#!/usr/bin/env bash
# Populate artifacts/dryrun/{baseline,opt}/*.json — the per-cell compile
# artifacts consumed by benchmarks/bench_roofline.py and
# scripts/render_experiments.py.  The sweep lowers + compiles every
# (arch × shape × mesh) cell (~40 min on a laptop-class host); cells that
# already have an artifact are skipped unless --force is passed through.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src python -m repro.launch.dryrun --all --tag baseline "$@"
PYTHONPATH=src python -m repro.launch.dryrun --all --tag opt --opt "$@"
