"""Docs smoke check: commands and file references in the documentation set
must match the repository, so the docs cannot silently rot.

Checked documents: README.md, docs/*.md, benchmarks/README.md.

Rules (stdlib-only, deterministic, no network):
  1. every relative markdown link target exists;
  2. every inline code span that looks like a repo path (contains "/" and a
     known extension, no wildcards) resolves against the repo root, the
     document's directory, src/, or src/repro/;
  3. every command in a fenced ``bash`` block references an existing
     python script / module / shell script, and any ``--flags`` it passes
     are accepted by the target's ``--help``;
  4. every fenced ``python`` block compiles (syntax check, no execution);
  5. no orphaned pages: every checked document (docs/*.md,
     benchmarks/README.md) must be reachable from README.md through
     relative markdown links — a page nobody links to silently rots.

Run:  python scripts/check_docs.py        (exit 1 + a report on problems)
"""
from __future__ import annotations

import re
import shlex
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = sorted(
    p for p in ([ROOT / "README.md", ROOT / "benchmarks" / "README.md"]
                + list((ROOT / "docs").glob("*.md")))
    if p.exists()
)

PATHLIKE = re.compile(r"^[\w./-]+\.(py|md|sh|yml|toml)$")
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SPAN = re.compile(r"`([^`\n]+)`")
FENCE = re.compile(r"^```(\w*)\s*$")

_help_cache: dict = {}


def resolve(path: str, doc: Path) -> bool:
    if any(c in path for c in "*<>{}"):
        return True  # wildcard/placeholder, not a literal reference
    cands = (ROOT, doc.parent, ROOT / "src", ROOT / "src" / "repro")
    return any((c / path).exists() for c in cands)


def module_file(mod: str) -> bool:
    rel = Path(*mod.split("."))
    for base in (ROOT, ROOT / "src"):
        if (base / rel).is_dir() or (base / rel).with_suffix(".py").exists():
            return True
    # installed third-party module (e.g. python -m pytest)
    import importlib.util

    try:
        return importlib.util.find_spec(mod.split(".")[0]) is not None
    except (ImportError, ValueError):
        return False


def help_text(target: list[str]) -> str:
    key = tuple(target)
    if key not in _help_cache:
        r = subprocess.run(
            [sys.executable, *target, "--help"], cwd=ROOT, text=True,
            capture_output=True, timeout=120,
        )
        _help_cache[key] = r.stdout + r.stderr
    return _help_cache[key]


def check_command(line: str, problems: list, where: str):
    try:
        words = shlex.split(line.split("#", 1)[0])
    except ValueError:
        return
    while words and re.fullmatch(r"\w+=\S*", words[0]):  # env assignments
        words.pop(0)
    if not words:
        return
    cmd, args = words[0], words[1:]
    if cmd in ("bash", "sh"):
        if args and not resolve(args[0], ROOT / "x"):
            problems.append(f"{where}: shell script {args[0]!r} not found")
        return
    if cmd not in ("python", "python3"):
        return  # pip/cd/etc: nothing to resolve
    target: list[str] = []
    if args and args[0] == "-m":
        if len(args) < 2 or not module_file(args[1]):
            problems.append(f"{where}: module {args[1] if len(args) > 1 else '?'!r} not found")
            return
        target = ["-m", args[1]]
        rest = args[2:]
    elif args and args[0].endswith(".py"):
        if not resolve(args[0], ROOT / "x"):
            problems.append(f"{where}: script {args[0]!r} not found")
            return
        target = [args[0]]
        rest = args[1:]
    else:
        return  # python -c / bare python
    flags = [w for w in rest if w.startswith("--")]
    # pytest's flag surface is its own contract; only check our scripts
    if flags and target != ["-m", "pytest"]:
        text = help_text(target)
        for f in flags:
            if f.split("=", 1)[0] not in text:
                problems.append(f"{where}: {' '.join(target)} does not accept {f!r}")
    if target == ["-m", "pytest"]:
        for w in rest:
            if w.startswith("tests/") and not (ROOT / w.split("::")[0]).exists():
                problems.append(f"{where}: test path {w!r} not found")


def check_doc(doc: Path, problems: list):
    rel = doc.relative_to(ROOT)
    lines = doc.read_text().splitlines()
    fence_lang = None
    py_block: list[str] = []
    py_start = 0
    for i, line in enumerate(lines, 1):
        m = FENCE.match(line)
        if m:
            if fence_lang == "python" and py_block:
                try:
                    compile("\n".join(py_block), f"{rel}:{py_start}", "exec")
                except SyntaxError as e:
                    problems.append(f"{rel}:{py_start}: python block does not compile: {e}")
            if fence_lang is None:
                fence_lang = m.group(1) or "text"
                py_block, py_start = [], i + 1
            else:
                fence_lang = None
            continue
        if fence_lang == "bash":
            stripped = line.strip().lstrip("$ ").strip()
            if stripped and not stripped.startswith("#"):
                check_command(stripped, problems, f"{rel}:{i}")
        elif fence_lang == "python":
            py_block.append(line)
        elif fence_lang is None:
            for link in LINK.findall(line):
                if "://" in link or link.startswith("#"):
                    continue
                if not resolve(link.split("#")[0], doc):
                    problems.append(f"{rel}:{i}: broken link {link!r}")
            for span in SPAN.findall(line):
                if "/" in span and PATHLIKE.match(span) and not resolve(span, doc):
                    problems.append(f"{rel}:{i}: dangling path reference {span!r}")


def check_reachability(problems: list):
    """Rule 5: every checked document must be reachable from README.md by
    following relative markdown links (BFS over the doc graph)."""
    seen: set = set()
    queue = [ROOT / "README.md"]
    while queue:
        doc = queue.pop()
        if doc in seen or not doc.exists():
            continue
        seen.add(doc)
        for link in LINK.findall(doc.read_text()):
            if "://" in link or link.startswith("#"):
                continue
            target = link.split("#")[0]
            if not target.endswith(".md"):
                continue
            for base in (ROOT, doc.parent):
                cand = (base / target)
                if cand.exists():
                    queue.append(cand.resolve())
                    break
    for page in DOCS:
        if page.resolve() not in seen:
            problems.append(
                f"{page.relative_to(ROOT)}: orphaned documentation page "
                "(not reachable from README.md via markdown links)")


def main() -> int:
    problems: list = []
    if not DOCS:
        print("no documents found to check", file=sys.stderr)
        return 1
    for doc in DOCS:
        check_doc(doc, problems)
    check_reachability(problems)
    if problems:
        print(f"{len(problems)} documentation problem(s):")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"docs ok: {len(DOCS)} documents checked "
          f"({', '.join(str(d.relative_to(ROOT)) for d in DOCS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
