"""Render EXPERIMENTS.md from dry-run artifacts + benchmark output.

Usage: PYTHONPATH=src python scripts/render_experiments.py
Reads artifacts/dryrun/{baseline,opt}/*.json and (if present)
bench_output.txt; writes EXPERIMENTS.md.  The §Perf hillclimb narrative is
maintained here (single source of truth for the report).
"""
from __future__ import annotations

import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
ART = REPO / "artifacts" / "dryrun"


def load(tag):
    rows, skips = {}, []
    d = ART / tag
    if not d.exists():
        return rows, skips
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            skips.append(r)
        elif r.get("ok"):
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows, skips


def fmt_s(x):
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x*1e3:8.1f}ms"


def roofline_table(rows, mesh):
    out = [
        "| arch | shape | bottleneck | t_compute | t_memory | t_collective | useful-FLOPs | state GiB/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        rl = r["roofline"]
        out.append(
            f"| {a} | {s} | {rl['bottleneck']} | {fmt_s(rl['t_compute_s'])} | "
            f"{fmt_s(rl['t_memory_s'])} | {fmt_s(rl['t_collective_s'])} | "
            f"{r['useful_flop_ratio']:.2f} | {r['memory']['peak_state_bytes_per_chip']/2**30:.1f} |"
        )
    return "\n".join(out)


def compare_table(base, opt, cells):
    out = [
        "| cell | term | baseline | optimized | Δ |",
        "|---|---|---|---|---|",
    ]
    for (a, s) in cells:
        b = base.get((a, s, "single"))
        o = opt.get((a, s, "single"))
        if not (b and o):
            out.append(f"| {a} × {s} | — | (missing) | | |")
            continue
        for term, key in (("compute", "t_compute_s"), ("memory", "t_memory_s"),
                          ("collective", "t_collective_s")):
            bv, ov = b["roofline"][key], o["roofline"][key]
            delta = f"{bv/ov:.1f}× better" if ov < bv else (f"{ov/bv:.1f}× worse" if bv > 0 else "—")
            out.append(f"| {a} × {s} | {term} | {fmt_s(bv)} | {fmt_s(ov)} | {delta} |")
        out.append(
            f"| {a} × {s} | useful-FLOPs | {b['useful_flop_ratio']:.2f} | "
            f"{o['useful_flop_ratio']:.2f} | |"
        )
    return "\n".join(out)


def bench_summaries():
    p = REPO / "bench_output.txt"
    fig4c = fig4d = "(run benchmarks)"
    if p.exists():
        for l in p.read_text().splitlines():
            if l.startswith("fig4c/uniform/SUMMARY"):
                fig4c = l.split(",", 2)[2]
            if l.startswith("fig4d/load_oriented/SUMMARY"):
                fig4d = l.split(",", 2)[2]
    return fig4c, fig4d


def bench_section():
    p = REPO / "bench_output.txt"
    if not p.exists():
        return "*(run `PYTHONPATH=src python -m benchmarks.run | tee bench_output.txt` to populate)*"
    lines = [l for l in p.read_text().splitlines() if l.startswith(("fig4", "quantum", "table3", "table1", "#"))]
    return "```\n" + "\n".join(lines) + "\n```"


def main():
    base, skips = load("baseline")
    opt, _ = load("opt")
    n_base = len(base)
    fig4c, fig4d = bench_summaries()
    hill_cells = [
        ("falcon-mamba-7b", "train_4k"),
        ("kimi-k2-1t-a32b", "decode_32k"),
        ("llama4-scout-17b-a16e", "prefill_32k"),
        ("qwen3-1.7b", "train_4k"),
    ]

    doc = f"""# EXPERIMENTS

Reproduction of *A Parallel SystemC Virtual Platform for Neuromorphic
Architectures* (Galicia et al., 2021) + multi-pod scale-out.  Environment:
CPU-only container (1 core, 35 GB RAM), jax 0.8.2; TPU v5e is the *target*
(197 TF bf16 / 819 GB/s HBM / ~50 GB/s ICI per chip); 512 placeholder
devices host the production meshes for lowering.  Regenerate this file with
`PYTHONPATH=src python scripts/render_experiments.py`.

## §Reproduction — paper claims vs measured

The paper's evaluation is pure *simulation speedup* (host runtime of the VP,
parallel vs sequential).  Measured on this host (see §Benchmarks for the
full per-layer tables; `benchmarks/bench_segmentation.py`):

| experiment | paper | this repo (measured) | notes |
|---|---|---|---|
| uniform segmentation speedup (Fig. 4c) | up to 2.3× | {fig4c} | 2 segments; vectorized lanes replace host threads (1-core container — DESIGN.md §2); thread backend ≈ 1× here, by construction |
| load-oriented speedup (Fig. 4d) | up to 3.3× | {fig4d} | 4 segments; matches the paper's sum-vs-max analysis |
| quantum sweet spot (§V-C) | N = 10K | roll-off above the latency bound reproduced (N=30K slower than 10K); at ÷8-scaled workloads the absolute optimum shifts to smaller N (fixed round overheads amortize differently) | same mechanism the paper reports |
| CIM vs RISC-V cycles (§V-B) | CIM ≫ CPU | 10–40× fewer simulated cycles | "alleviates the von Neumann bottleneck" reproduced architecturally |
| backend equivalence | (implied by SystemC semantics) | bit-identical across sequential/threads/vmap/shard_map | property-tested (tests/test_core_decoupling.py) |

Scaled Table III dims (÷8) are the default on this 1-core host; speedup
*ratios* are scale-stable (set `REPRO_FULL_BENCH=1` for full dims).

## §Dry-run

`launch/dryrun.py` lowered **and compiled** every (architecture × shape)
cell on both production meshes — (16,16)=256 chips and (2,16,16)=512 chips —
with full in/out shardings (TP over `model`, batch over `(data,pod)`, EP +
FSDP for MoE, ZeRO-1 optimizer states, split-KV decode caches).
**{n_base} cell-compilations succeeded** ({n_base//2} cells × 2 meshes);
artifacts (memory_analysis, loop-aware cost, collective schedule) in
`artifacts/dryrun/baseline/`.

Documented skips ({len(skips)}): `long_500k` for the 8 pure full-attention
archs (quadratic attention at 524k ctx has no sub-quadratic path in those
architectures; it *runs* for falcon-mamba [SSM] and zamba2 [hybrid]).

Memory notes (per-chip state = arguments + temporaries, from
`memory_analysis()`):
- kimi-k2-1t-a32b train_4k: ~103 GiB/chip single-pod, ~81 GiB multi-pod —
  a 1T-param model with AdamW does not fit 256–512 v5e chips even with
  bf16 params + int8 moments + ZeRO-1 + FSDP + full remat; the dry-run
  records the honest requirement (≳4 pods for capacity).  All other archs'
  serve cells fit 16 GB/chip; several train cells are over (recorded per
  cell below) — batch-256×4k training of ≥34B models wants more chips,
  which is the expected production answer.
- whisper-tiny / llama4 head counts not divisible by TP=16 are handled by
  policy (replicate vs pad+shard, see §Perf hillclimb 4).

## §Roofline — method

Terms per cell (TPU v5e constants), derived from the *compiled, SPMD-
partitioned* HLO:

```
compute    = per-chip HLO FLOPs / 197e12
memory     = per-chip HLO bytes accessed / 819e9
collective = per-chip collective operand bytes / 50e9
```

Two measurement details that matter (analysis/hlo_cost.py):
1. XLA's `cost_analysis()` counts every computation **once** — verified: a
   10-iteration scan of a matmul reports 1× its FLOPs.  All models here scan
   over layers, so costs are re-derived by walking the HLO call graph and
   multiplying `while` bodies by their `known_trip_count` (exact for jax
   scans; validated to <2% on closed-form programs, incl. nested scans and
   sharded modules — tests/test_analysis.py, tests/test_distributed.py).
2. Byte counts reflect XLA:**CPU** fusion boundaries, which are more
   granular than the TPU backend's (e.g. fp32 norm chains split into 3–4
   top-level fusions that a TPU build fuses into one).  The memory terms
   are therefore *upper bounds*; deltas between configurations remain
   meaningful because both sides carry the same convention.  MODEL_FLOPS =
   6·N_active·D (train) / 2·N_active·D (inference); `useful-FLOPs` =
   MODEL_FLOPS / HLO_FLOPs, catching remat/dispatch/replication waste.

## §Roofline — baseline table (single-pod, 256 chips)

{roofline_table(base, "single")}

### Multi-pod (512 chips, 2 pods over DCN)

{roofline_table(base, "multi")}

Reading the table: *every* cell is memory-term-dominated under the CPU-HLO
byte convention; the interesting signal is the relative magnitudes and the
useful-FLOPs column.  Worst offenders picked for hillclimbing: falcon-mamba
train (t_mem 364 s — (B,S,D,N) selective-scan materialization), kimi-k2
decode (useful-FLOPs 0.00, collective-heavy FSDP weight gathers), and
llama4 prefill (useful-FLOPs 0.12 — replicated attention).  qwen3 train_4k
was hillclimbed as the canonical dense cell.

## §Perf — hillclimb log (hypothesis → change → measure → verdict)

**1. falcon-mamba-7b × train_4k** — baseline: mem {fmt_s(base[("falcon-mamba-7b","train_4k","single")]["roofline"]["t_memory_s"]) if ("falcon-mamba-7b","train_4k","single") in base else "?"}, compute {fmt_s(base[("falcon-mamba-7b","train_4k","single")]["roofline"]["t_compute_s"]) if ("falcon-mamba-7b","train_4k","single") in base else "?"} (≈340× memory-bound).
- *Hypothesis 1*: the (B,S,d_inner,N) decay/drive tensors (N=16× activation
  size) are materialized at full sequence length before the chunk scan;
  expanding them per chunk inside the scan body (+ jax.checkpoint) should
  cut the term ~N×.  → **confirmed**: 363.6 s → 121.4 s (3.0×).
- *Hypothesis 2*: replacing the intra-chunk associative scan (log-depth
  sweeps ≈ 8 passes over the expanded tensors) with a sequential
  within-chunk lax.scan should remove those passes.  → **refuted**: 121 s →
  710 s (5.9× *worse*) — per-step while-loop boundaries defeat XLA:CPU
  fusion entirely; reverted.  The true register-resident form is the Pallas
  `ssm_scan` kernel (kernels/ssm_scan, validated vs oracle), whose interpret-
  mode HLO streams inputs exactly once; on TPU the kernel is the production
  path.
- Net: **3.0× on the dominant term**, useful-FLOPs 0.82 (unchanged — the
  fix moves bytes, not FLOPs).

**2. kimi-k2-1t-a32b × decode_32k** — baseline: coll 326 ms, mem 4.47 s,
useful-FLOPs 0.004.
- *Hypothesis*: per-layer FSDP all-gathers of expert weights (2.1 GB/layer
  over the data axis) dominate decode, and the dropless dispatch buffer
  (capacity = top_k·T_local over 24 local experts) wastes ~24× FLOPs.
  Moving *tokens* (≤128 × d_model ≈ MBs) instead of *weights* (GBs) —
  all-gather the token batch over `data`, compute each chip's
  (expert-subset × ff-slice) contribution with resident weights (the silu
  gate is elementwise in ff, so ff-slicing is exact), one psum back —
  should collapse the collective term.  → **confirmed**: collective
  326 ms → 13.7 ms (**23.8×**).  Memory term stayed ≈5 s: with only 256
  chips every chip still reads its full 8 GB expert-weight residency per
  step — that is the *true* arithmetic-intensity wall of 1-token-per-
  sequence MoE decode at this scale (fix: more chips or wider decode
  batches, not scheduling).
- Bonus: the same path serves llama4 decode (also FSDP).

**3. llama4-scout-17b-a16e × prefill_32k** — baseline: mem 804 s,
useful-FLOPs 0.12.
- *Hypothesis*: 40 q-heads % 16 ≠ 0 made the sharding policy *replicate*
  attention — every chip computes all 40 heads at 32k ctx (16× waste).
  Padding to 48 heads (20% pad) with masked pad-head outputs shards
  16-way.  → **confirmed**: 804 s → **85.7 s (9.4×)**; useful-FLOPs
  0.12 → **0.65**.
- Also lifts llama4 train_4k and decode_32k (same replication).

**4. qwen3-1.7b × train_4k** (canonical dense cell) — baseline: mem
7.98 s, compute 0.36 s, useful-FLOPs 0.60.
- *Hypothesis 1*: dense-masked fp32 attention scores (B,H,S,S) dominate →
  flash attention (triangular chunk-pair scan fwd + custom-VJP flash
  backward, validated to 1e-6 vs dense).  → **partially refuted**: FLOPs
  cleaned up (useful 0.60 → 0.66; causal 2× overcount gone; compute term
  356 → 328 ms) but the memory term *rose* slightly (7.98 → 8.51 s): at
  TP=16 this model has **one head per chip** — scores were only 268 MB and
  never dominated.  Per-op attribution showed the real traffic: 37% remat
  recompute + 43% bf16↔fp32 conversion fusions around norms/residuals.
- *Hypothesis 2*: selective remat (`save_dots` policy) removes recompute
  traffic.  → **refuted**: compute improved (−22%) but saving the dot
  stack raised the memory term to 11.0 s; reverted.
- *Hypothesis 3*: `remat="none"` (28 small layers might afford saved
  activations).  → **refuted**: 17.3 s (saved-stack traffic ≫ recompute);
  reverted.
- *Hypothesis 4*: mixed-precision norms (stats fp32, normalize bf16) halve
  the conversion chains.  → **neutral** on CPU-HLO fusion boundaries
  (8.51 → 8.49 s): the conversions sit at boundaries the CPU backend
  refuses to fuse regardless of dtype; on the TPU backend these fuse into
  neighboring ops.  Kept (it is standard practice and strictly fewer
  bytes).
- Verdict: qwen3's train cell is *conversion/remat-boundary* bound in this
  measurement convention, not attention bound — three consecutive <5%
  changes on the dominant term; stopped per protocol.  The confirmed FLOP
  cleanup (flash) is kept for the optimized configuration.

### Stop criteria
Hillclimbs stopped after three consecutive <5% iterations on the dominant
term (qwen3) or after the dominant term moved to a structural wall
(kimi decode: weight residency; falcon: kernel-fusion limit of the CPU
backend).

## §Perf — baseline vs optimized (hillclimbed cells)

{compare_table(base, opt, hill_cells)}

### Full optimized sweep

The `--opt` configuration (flash train attention + mixed-precision norms +
all unconditional fixes: per-chunk mamba expansion, token-moving decode
MoE, head padding) over all cells is tagged `opt` in `artifacts/dryrun/`
({len(opt)} cells compiled).

{roofline_table(opt, "single") if opt else "*(opt sweep pending)*"}

## §Benchmarks (paper tables/figures)

{bench_section()}

## Honest limitations

- 1 CPU core: thread-backend parallelism cannot manifest; the measured
  parallel speedups use the vectorized backend (DESIGN.md §2 argues this is
  the TPU-native reading of the paper's mechanism), and the shard_map
  backend is proven by lowering + small-mesh equivalence tests.
- Roofline bytes follow XLA:CPU fusion granularity (upper bounds); FLOPs
  and collective bytes are backend-robust.
- The CIM analog crossbar is modeled bit-exactly as integer math with
  DAC/ADC saturation; no device noise model (out of the paper's scope —
  its calculator is also behavioral).
- Intra-quantum DRAM load-after-store is not forwarded (posted-write TLM
  semantics; benchmark programs never do it — documented in vp/platform.py).
"""
    (REPO / "EXPERIMENTS.md").write_text(doc)
    print(f"EXPERIMENTS.md written: {n_base} baseline cells, {len(opt)} opt cells")


if __name__ == "__main__":
    main()
