"""Roofline table from the dry-run artifacts: three terms per
(arch × shape × mesh) cell + dominant bottleneck + MODEL_FLOPS ratio.

Reads artifacts/dryrun/<tag>/*.json (produced by launch/dryrun.py); emits
the CSV consumed by EXPERIMENTS.md §Roofline.  Missing artifacts are
reported, not recomputed (the sweep takes ~40 min; run
``bash scripts/sweep_dryrun.sh`` to (re)populate).
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(tag="baseline"):
    rows, skips, missing = [], [], []
    d = ART / tag
    if not d.exists():
        return [], [], ["<no artifacts — run scripts/sweep_dryrun.sh>"]
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            skips.append(r)
        elif r.get("ok"):
            rows.append(r)
        else:
            missing.append(f"{r['arch']}×{r['shape']}×{r['mesh']}: {r.get('error','')[:80]}")
    return rows, skips, missing


def main(out=print, tag="baseline"):
    rows, skips, missing = load(tag)
    for r in rows:
        rl = r["roofline"]
        t_b = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        out(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},{t_b*1e6:.0f},"
            f"bneck={rl['bottleneck']} t_comp={rl['t_compute_s']*1e3:.2f}ms "
            f"t_mem={rl['t_memory_s']*1e3:.2f}ms t_coll={rl['t_collective_s']*1e3:.2f}ms "
            f"useful_flops={r['useful_flop_ratio']:.3f} "
            f"state_GiB={r['memory']['peak_state_bytes_per_chip']/2**30:.2f}"
        )
    for s in skips:
        out(f"roofline/{s['arch']}/{s['shape']}/SKIP,0,{s['skipped']}")
    for m in missing:
        out(f"roofline/MISSING,0,{m}")


if __name__ == "__main__":
    main()
