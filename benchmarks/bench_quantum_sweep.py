"""Paper §V-C: speedup vs quantum size N.

The paper found N = 10K optimal: larger quanta amortize synchronization, but
past the channel-latency bound the RISC-V+memory path stalls (slots burn
with time capped at the decoupling limit) and speed decreases — our
mechanism reproduces exactly that roll-off (the controller clamps local time
at ``min_peer(t)+latency``; oversized quanta waste host work on idle slots).
"""
from __future__ import annotations

from benchmarks.common import SCALE, build_workload, timed_run
from repro.vp import workloads as wl

LATENCY = 10_000
QUANTA = [2_000, 10_000, 30_000]


def run(mode: str = "mixed", layer=None):
    layer = layer or wl.TABLE_III[2].scaled(SCALE)  # ImageNet-conv1
    rows = []
    for q in QUANTA:
        cfg, states, pending, _ = build_workload(layer, "uniform", mode, LATENCY)
        t_sq, cyc, _ = timed_run(cfg, states, pending, "sequential", q)
        t_pll, _, _ = timed_run(cfg, states, pending, "vmap", q)
        rows.append({"quantum": q, "sq_s": t_sq, "pll_s": t_pll, "speedup": t_sq / t_pll})
    return rows


def main(out=print):
    rows = run()
    best = max(rows, key=lambda r: 1 / r["pll_s"])
    for r in rows:
        tag = " <= best" if r is best else ""
        out(f"quantum_sweep/N={r['quantum']},{r['pll_s']*1e6:.0f},"
            f"speedup={r['speedup']:.2f}x{tag}")
    out(f"quantum_sweep/SUMMARY,0,best_N={best['quantum']} "
        f"(paper: 10K; latency={LATENCY} bounds useful quanta)")


if __name__ == "__main__":
    main()
