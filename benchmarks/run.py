"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see benchmarks/README.md
for the per-section line formats).  ``--json`` additionally captures every
emitted line into a JSON report.  Set REPRO_FULL_BENCH=1 (or pass
``--full``) for the unscaled Table III dimensions — the default divides
h/w/p by 8 so the whole suite finishes in minutes on a 1-core container;
speedup *ratios* are scale-stable, see EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# runnable as ``python benchmarks/run.py`` from anywhere: put the repo root
# (the ``benchmarks`` namespace package) and src/ (``repro``) on the path
_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def provenance() -> dict:
    """Where this report came from: jax/backend/device/CPU-count/git-SHA.

    Embedded in every ``--json`` report so baselines are comparable across
    machines — ``--check --baseline OLD.json`` warns (never fails) when two
    reports were measured on different stacks.
    """
    import subprocess

    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_ROOT, text=True,
            capture_output=True, timeout=10).stdout.strip() or "unknown"
    except OSError:
        sha = "unknown"
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "git_sha": sha,
    }


def provenance_warnings(ours: dict, baseline: dict) -> list:
    """Human-readable mismatch lines between two provenance dicts."""
    warns = []
    for key in sorted(set(ours) | set(baseline)):
        a, b = baseline.get(key), ours.get(key)
        if a != b:
            warns.append(f"provenance mismatch: {key}: "
                         f"baseline {a!r} vs this run {b!r}")
    return warns


def sections():
    from benchmarks import (
        bench_feature_matrix,
        bench_quantum_sweep,
        bench_roofline,
        bench_segmentation,
        bench_snn,
        bench_vmm_workloads,
    )

    return [
        ("feature_matrix", "Table I  — simulator feature matrix",
         bench_feature_matrix.main),
        ("vmm_workloads", "Table III / §V-B — VMM workloads (riscv vs cim)",
         bench_vmm_workloads.main),
        ("segmentation", "Fig. 4c/4d — segmentation speedups (sq vs pll)",
         bench_segmentation.main),
        ("snn", "SNN — spiking inference, spikes/sec per segmentation "
         "(feed-forward + recurrent/lateral) + wide-layer naive vs "
         "traffic-aware placement", bench_snn.main),
        ("quantum_sweep", "§V-C — quantum-size sweep", bench_quantum_sweep.main),
        ("roofline", "§Roofline — dry-run derived terms (40 cells)",
         bench_roofline.main),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks/run.py",
        description="Run the paper-reproduction benchmark suite "
                    "(CSV lines on stdout; optional JSON report).")
    ap.add_argument("--only", metavar="KEY", default=None,
                    help="run a single section by key (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list section keys and titles, then exit")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {section: [emitted lines]} plus timings "
                         "to PATH as JSON")
    ap.add_argument("--full", action="store_true",
                    help="unscaled Table III dimensions "
                         "(equivalent to REPRO_FULL_BENCH=1; much slower)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any emitted line carries a failed "
                         "verification flag (ok=False / correct=False / "
                         "supported=False) — CI smoke: perf runs cannot "
                         "silently break correctness")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="a previous --json report to compare provenance "
                         "against; with --check, mismatches (jax version, "
                         "backend, device kind, CPU count, git SHA) print "
                         "warnings — numbers from different stacks are not "
                         "comparable, but this never fails the run")
    args = ap.parse_args(argv)
    if args.full:
        os.environ["REPRO_FULL_BENCH"] = "1"  # before benchmarks.common import

    secs = sections()
    if args.list:
        for key, title, _ in secs:
            print(f"{key:16s} {title}")
        return
    if args.only is not None:
        secs = [s for s in secs if s[0] == args.only]
        if not secs:
            sys.exit(f"unknown section {args.only!r}; try --list")

    report = {}
    t0 = time.time()
    print("name,us_per_call,derived")
    for key, title, fn in secs:
        print(f"# === {title} ===", flush=True)
        lines = []

        def out(line):
            print(line)
            lines.append(str(line))

        t1 = time.time()
        fn(out=out)
        report[key] = {"title": title, "lines": lines,
                       "seconds": round(time.time() - t1, 3)}
    total = time.time() - t0
    print(f"# total bench time: {total:.1f}s")
    prov = provenance()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"sections": report, "total_seconds": round(total, 3),
                       "full": bool(os.environ.get("REPRO_FULL_BENCH") == "1"),
                       "provenance": prov},
                      f, indent=2)
        print(f"# json report -> {args.json}")
    if args.baseline and args.check:
        with open(args.baseline) as f:
            base_prov = json.load(f).get("provenance", {})
        for w in provenance_warnings(prov, base_prov):
            print(f"# WARNING: {w}", file=sys.stderr)
    if args.check:
        bad = [line for sec in report.values() for line in sec["lines"]
               if any(flag in line for flag in
                      ("ok=False", "correct=False", "supported=False"))]
        if bad:
            print(f"# VERIFICATION FAILED on {len(bad)} line(s):", file=sys.stderr)
            for line in bad:
                print(f"#   {line}", file=sys.stderr)
            sys.exit(1)
        print(f"# verification flags clean across "
              f"{sum(len(s['lines']) for s in report.values())} lines")


if __name__ == "__main__":
    main()
