"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Set REPRO_FULL_BENCH=1 for
the unscaled Table III dimensions (the default divides h/w/p by 8 so the
whole suite finishes in minutes on this 1-core container; speedup *ratios*
are scale-stable, see EXPERIMENTS.md).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_feature_matrix,
        bench_quantum_sweep,
        bench_roofline,
        bench_segmentation,
        bench_snn,
        bench_vmm_workloads,
    )

    sections = [
        ("Table I  — simulator feature matrix", bench_feature_matrix.main),
        ("Table III / §V-B — VMM workloads (riscv vs cim)", bench_vmm_workloads.main),
        ("Fig. 4c/4d — segmentation speedups (sq vs pll)", bench_segmentation.main),
        ("SNN — spiking inference, spikes/sec per segmentation", bench_snn.main),
        ("§V-C — quantum-size sweep", bench_quantum_sweep.main),
        ("§Roofline — dry-run derived terms (40 cells)", bench_roofline.main),
    ]
    t0 = time.time()
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# === {title} ===", flush=True)
        fn(out=print)
    print(f"# total bench time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
