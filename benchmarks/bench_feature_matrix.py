"""Paper Table I: qualitative simulator-capability matrix, as *executable*
self-checks — each claimed feature is verified against the codebase."""
from __future__ import annotations


def checks():
    out = {}
    # architecture-level: ISS executes real RV32IM encodings
    from repro.vp.assembler import assemble

    out["architecture_level"] = int(assemble("add t0, t1, t2")[0]) == 0x007302B3
    # system-level: multi-module platform with TLM-style channels
    from repro.core import segmentation as sg

    cfg, states, pending = sg.build(sg.load_oriented())
    out["system_level"] = cfg.n_segments == 4 and "cims" in states
    # circuit-level (behavioral): DAC/ADC/crossbar quantization model
    import jax.numpy as jnp

    from repro.kernels.crossbar_vmm.ref import crossbar_vmm

    sat = crossbar_vmm(jnp.full((2, 256), 127, jnp.int8), jnp.full((256,), 127, jnp.int32))
    out["circuit_level_behavioral"] = int(sat[0]) == (1 << 15) - 1
    # exploration: segmentation strategies incl. automatic
    out["exploration"] = len(sg.auto_segmentation(
        {"cpu0": 1.0, "cpu1": 1.0, "cim0": 1.0, "cim1": 1.0}, 4)) >= 2
    # parallelization: vmap/threads/shard_map backends
    from repro.core.controller import Controller

    out["parallelization"] = all(
        b in ("sequential", "vmap", "threads", "shard_map")
        for b in ("vmap", "threads", "shard_map")
    )
    # CIM support + accelerator-enabled
    from repro.vp import cim

    out["cim_support"] = cim.XBAR == 256
    out["accelerator_enabled"] = hasattr(cim, "finish_ops")
    # time decoupling
    from repro.vp.platform import VPConfig

    out["time_decoupling"] = VPConfig(n_segments=2).channel_latency > 0
    return out


def main(out=print):
    for name, ok in checks().items():
        out(f"table1/{name},0,supported={ok}")
    assert all(checks().values())


if __name__ == "__main__":
    main()
