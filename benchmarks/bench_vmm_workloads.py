"""Paper Table III + §V-B: every network layer in both execution modes.

Reports simulated cycles (the architectural result: CIM offload alleviates
the von Neumann bottleneck), instructions executed, DRAM traffic and host
runtime — plus the crossbar tiles derived from this framework's own assigned
LM architectures (vp/workloads.from_arch), closing the loop between the
paper's benchmark methodology and the training stack.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, build_workload, timed_run, verify

SCALE = 1 if FULL else 3  # architectural cycles need compute >> sync overhead
from repro.vp import workloads as wl

QUANTUM = 10_000
LATENCY = 10_000


def run(layers=None):
    rows = []
    for layer in layers or [l.scaled(SCALE) for l in wl.TABLE_III]:
        res = {}
        for mode in ("riscv", "cim"):
            cfg, states, pending, job = build_workload(layer, "uniform", mode, LATENCY)
            host, cyc, ctl = timed_run(cfg, states, pending, "vmap", QUANTUM)
            stats = ctl.stats()
            res[mode] = {
                "host_s": host,
                "sim_cycles": cyc,
                "instrs": int(stats["instructions"].sum()),
                "dram_reads": int(stats["dram"]["reads"].sum()),
                "correct": verify(ctl, job, layer),
            }
        rows.append({"layer": layer.name, "h": layer.h, "w": layer.w, "p": layer.p, **{
            f"{m}_{k}": v for m, d in res.items() for k, v in d.items()
        }})
    return rows


def main(out=print):
    rows = run()
    for r in rows:
        cim_speed = r["riscv_sim_cycles"] / max(r["cim_sim_cycles"], 1)
        out(f"table3/{r['layer']}({r['h']}x{r['w']}x{r['p']}),{r['cim_host_s']*1e6:.0f},"
            f"riscv_cycles={r['riscv_sim_cycles']} cim_cycles={r['cim_sim_cycles']} "
            f"cim_arch_speedup={cim_speed:.1f}x dram_reads_riscv={r['riscv_dram_reads']} "
            f"dram_reads_cim={r['cim_dram_reads']} ok={r['riscv_correct'] and r['cim_correct']}")
    # crossbar tiles from an assigned architecture (framework integration;
    # cim mode only — the 256×256 tiles take minutes on the scalar ISS path)
    from benchmarks.common import build_workload, timed_run, verify
    for layer in wl.from_arch("qwen3-1.7b", max_tiles=2):
        cfg, states, pending, job = build_workload(layer, "uniform", "cim", LATENCY)
        host, cyc, ctl = timed_run(cfg, states, pending, "vmap", QUANTUM)
        out(f"table3/from_arch/{layer.name},{host*1e6:.0f},"
            f"cim_cycles={cyc} ok={verify(ctl, job, layer)}")


if __name__ == "__main__":
    main()
