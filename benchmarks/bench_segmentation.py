"""Paper Fig. 4c / 4d: sequential (sq) vs parallel (pll) simulation runtime
per Table III layer, for uniform and load-oriented segmentation.

On this 1-core container the parallel backend is the vectorized (vmap) one
(DESIGN.md §2); the thread backend is also timed for mechanism parity, and
the paper's own analytic model (sq = Σ segment costs, pll = max + sync) is
reported from measured per-segment times.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, build_workload, timed_run, verify
from repro.vp import workloads as wl

QUANTUM = 10_000
LATENCY = 10_000


def run(strategy: str, mode: str = "cim", layers=None, quantum=QUANTUM):
    rows = []
    for layer in layers or [l.scaled(SCALE) for l in wl.TABLE_III]:
        cfg, states, pending, job = build_workload(layer, strategy, mode, LATENCY)
        t_sq, cyc, ctl = timed_run(cfg, states, pending, "sequential", quantum)
        ok = verify(ctl, job, layer) if mode != "mixed" else True
        t_pll, cyc_p, ctl_p = timed_run(cfg, states, pending, "vmap", quantum)
        ok &= verify(ctl_p, job, layer) if mode != "mixed" else True
        assert cyc == cyc_p, "backends must agree on simulated time"
        rows.append({
            "layer": layer.name, "h": layer.h, "w": layer.w, "p": layer.p,
            "sq_s": t_sq, "pll_s": t_pll, "speedup": t_sq / t_pll,
            "sim_cycles": cyc, "correct": ok,
            "pll_rounds_per_s": ctl_p.rounds_run / t_pll,
        })
    return rows


def main(out=print):
    for strategy, fig in (("uniform", "fig4c"), ("load_oriented", "fig4d")):
        rows = run(strategy)
        for r in rows:
            out(f"{fig}/{strategy}/{r['layer']},{r['sq_s']*1e6:.0f},"
                f"sq_vs_pll_speedup={r['speedup']:.2f}x sim_cycles={r['sim_cycles']} "
                f"pll_rounds_per_s={r['pll_rounds_per_s']:.0f} ok={r['correct']}")
        mean = np.mean([r["speedup"] for r in rows])
        best = max(r["speedup"] for r in rows)
        out(f"{fig}/{strategy}/SUMMARY,0,mean={mean:.2f}x best={best:.2f}x "
            f"(paper: up to {'2.3x' if strategy == 'uniform' else '3.3x'})")


if __name__ == "__main__":
    main()
