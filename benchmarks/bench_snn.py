"""SNN inference throughput per segmentation strategy — the event-driven
analogue of the paper's Fig. 5 speedup table.

For each strategy, a multi-layer LIF network runs to completion on the
sequential (sq) baseline and the parallel (pll/vmap) backend; we report
host time, simulated spikes per host-second, and the sq/pll speedup.
Spike totals are asserted identical across backends (bit-exact property)
and against the pure-jnp oracle — a speedup on wrong spikes is worthless.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import snn
from repro.core.controller import Controller

QUANTUM = 32  # CPU-free event-driven run: tiny instruction window, full ticks
SIZES = (128, 96, 64, 10)
T_STEPS = 24


def _timed(cfg, states, pending, backend, max_rounds=400):
    warm = Controller(cfg, states, pending, backend=backend, quantum=QUANTUM)
    warm.round()  # compile
    jax.block_until_ready(warm._states_l if warm._list_mode else warm.states)
    ctl = Controller(cfg, states, pending, backend=backend, quantum=QUANTUM)
    t0 = time.perf_counter()
    ctl.run(max_rounds=max_rounds, check_every=2)
    host = time.perf_counter() - t0
    return host, ctl


def run(strategies=("uniform", "load_oriented", "auto"), sizes=SIZES,
        t_steps=T_STEPS, seed=2):
    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.5, seed=seed)
    rows = []
    for strategy in strategies:
        placement = None
        if strategy == "auto":
            descs, placement = snn.auto_segmentation_for(job.layers, n_segments=4)
        else:
            descs = snn.segmentation_for(len(job.layers), strategy, n_segments=4)
        cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster,
                                                   placement=placement)
        t_sq, ctl_sq = _timed(cfg, states, pending, "sequential")
        t_pll, ctl_pll = _timed(cfg, states, pending, "vmap")
        spikes = snn.total_spikes(ctl_pll.result_states())
        assert spikes == snn.total_spikes(ctl_sq.result_states()), \
            "backends disagree on spike totals"
        counts = snn.output_spike_counts(ctl_pll.result_states(), meta)
        ok = bool(np.array_equal(counts, job.expected_counts))
        rows.append({
            "strategy": strategy, "segments": len(descs),
            "sq_s": t_sq, "pll_s": t_pll, "speedup": t_sq / t_pll,
            "spikes": spikes,
            "sq_spikes_per_s": spikes / t_sq, "pll_spikes_per_s": spikes / t_pll,
            "correct": ok,
        })
    return rows


def main(out=print):
    net = "x".join(str(s) for s in SIZES)
    for r in run():
        out(f"fig5snn/{r['strategy']}/{net},{r['sq_s']*1e6:.0f},"
            f"sq_vs_pll_speedup={r['speedup']:.2f}x"
            f" spikes={r['spikes']}"
            f" sq_spk_per_s={r['sq_spikes_per_s']:.0f}"
            f" pll_spk_per_s={r['pll_spikes_per_s']:.0f}"
            f" segments={r['segments']} ok={r['correct']}")


if __name__ == "__main__":
    main()
