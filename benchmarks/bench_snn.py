"""SNN inference throughput per segmentation strategy — the event-driven
analogue of the paper's Fig. 5 speedup table.

For each strategy, a multi-layer LIF network runs to completion on the
sequential (sq) baseline and the parallel (pll/vmap) backend; we report
host time, simulated spikes per host-second, and the sq/pll speedup.
Spike totals are asserted identical across backends (bit-exact property)
and against the pure-jnp oracle — a speedup on wrong spikes is worthless.

The *recurrent* scenario opens the cyclic workload class (TrueNorth/RANC
cores are dominated by recurrent wiring): an Elman-style self-recurrent
hidden layer, a winner-take-all self-inhibiting output pool, and a
backward feedback edge run over a bounded tick horizon, verified
bit-exactly against the cycle-aware oracle — spikes/sec per segmentation
strategy shows how placement copes when every hot layer also talks to
itself and to earlier layers.

The *hybrid* scenario is the paper's headline co-simulation: live RISC-V
CPUs, dense-mode CIM units, and spiking layers in ONE platform — CPU0
runs the dense VMM offload while CPU1 injects the SNN raster through
tick-addressed CIM_REG_SPIKE stores and reads the output counts back via
CIM_REG_COUNTS, publishing them to shared DRAM.  Both halves are
oracle-verified while timed, per platform shape (split / packed /
traffic-aware auto).

The *faults* scenario prices the fault-injection subsystem
(docs/faults.md): the dispatch-bound megaloop workload runs fault-free
(``faults=None``, compiled out) and with live seeded transport faults,
asserting <10% overhead and fused/per-round bit-identity, then sweeps the
drop rate through ``snn.degradation_sweep`` and requires the fidelity
curve to start at exactly 1.0 and fall monotonically.

The *wide* scenario exercises multi-crossbar layers: a 600-neuron hidden
layer shards into three row stripes, and its 600-axon consumer tiles into
a co-located column group.  Naive (chain-order uniform) placement is
compared against spike-traffic-aware placement: the naive run doubles as
the profiling pass (measured per-unit spike rates -> traffic matrix), and
``auto_segmentation_for(traffic=...)`` re-places the shard groups to
minimize cross-segment spike traffic under the slot budget — packing the
chatty groups densely also shrinks the simulated platform, which is where
the spikes/sec win comes from.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

# runnable standalone (``python benchmarks/bench_snn.py --trace``): mirror
# run.py's bootstrap so the repro package resolves from any cwd
_ROOT = Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from repro import snn
from repro.core.controller import Controller

QUANTUM = 32  # CPU-free event-driven run: tiny instruction window, full ticks
SIZES = (128, 96, 64, 10)
T_STEPS = 24
WIDE_SIZES = (128, 600, 64)  # 600 out -> 3 row stripes; 600 in -> 3-tile group
WIDE_T_STEPS = 10


def _timed(cfg, states, pending, backend, max_rounds=400, fused=None,
           quantum=QUANTUM, obs=None):
    warm = Controller(cfg, states, pending, backend=backend, quantum=quantum,
                      obs=obs)
    warm.run(max_rounds=2, check_every=2, fused=fused)  # compile round + megastep
    warm.block_until_ready()
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum,
                     obs=obs)
    t0 = time.perf_counter()
    ctl.run(max_rounds=max_rounds, check_every=2, fused=fused)
    host = time.perf_counter() - t0
    return host, ctl


def run(strategies=("uniform", "load_oriented", "auto"), sizes=SIZES,
        t_steps=T_STEPS, seed=2):
    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.5, seed=seed)
    rows = []
    for strategy in strategies:
        placement = None
        if strategy == "auto":
            descs, placement = snn.auto_segmentation_for(job.layers, n_segments=4)
        else:
            descs = snn.segmentation_for(len(job.layers), strategy, n_segments=4)
        cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster,
                                                   placement=placement)
        t_sq, ctl_sq = _timed(cfg, states, pending, "sequential")
        t_pll, ctl_pll = _timed(cfg, states, pending, "vmap")
        spikes = snn.total_spikes(ctl_pll.result_states())
        assert spikes == snn.total_spikes(ctl_sq.result_states()), \
            "backends disagree on spike totals"
        counts = snn.output_spike_counts(ctl_pll.result_states(), meta)
        ok = bool(np.array_equal(counts, job.expected_counts))
        rows.append({
            "strategy": strategy, "segments": len(descs),
            "sq_s": t_sq, "pll_s": t_pll, "speedup": t_sq / t_pll,
            "spikes": spikes,
            "sq_spikes_per_s": spikes / t_sq, "pll_spikes_per_s": spikes / t_pll,
            "rounds": ctl_pll.rounds_run,
            "pll_rounds_per_s": ctl_pll.rounds_run / t_pll,
            "correct": ok,
        })
    return rows


REC_SIZES = (96, 80, 24)  # Elman hidden + WTA output + feedback edge
REC_T_STEPS = 16


def run_recurrent(strategies=("uniform", "load_oriented", "auto"),
                  sizes=REC_SIZES, t_steps=REC_T_STEPS, seed=3):
    """Recurrent/lateral connectivity per segmentation strategy.

    The cyclic analogue of ``run``: a ``snn_recurrent_job`` network (the
    hidden layer feeds itself laterally, the output pool self-inhibits,
    and a backward edge closes the loop) runs over its bounded tick
    horizon on the sq and pll backends.  Cyclic edges triple the AER
    fan-out of the hot layers, so this scenario stresses exactly the
    cross-segment traffic the placement strategies trade in; spike totals
    are verified across backends and against the cycle-aware oracle.
    """
    job = snn.snn_recurrent_job(sizes, t_steps=t_steps, rate=0.5, seed=seed)
    rows = []
    for strategy in strategies:
        placement = None
        if strategy == "auto":
            descs, placement = snn.auto_segmentation_for(
                job.layers, n_segments=4, edges=job.edges)
        else:
            descs = snn.segmentation_for(job.layers, strategy, n_segments=4,
                                         edges=job.edges)
        cfg, states, pending, meta = snn.build_snn(
            job.layers, descs, job.raster, edges=job.edges,
            n_ticks=job.n_ticks, placement=placement)
        t_sq, ctl_sq = _timed(cfg, states, pending, "sequential")
        t_pll, ctl_pll = _timed(cfg, states, pending, "vmap")
        spikes = snn.total_spikes(ctl_pll.result_states())
        assert spikes == snn.total_spikes(ctl_sq.result_states()), \
            "backends disagree on spike totals"
        counts = snn.output_spike_counts(ctl_pll.result_states(), meta)
        ok = bool(np.array_equal(counts, job.expected_counts))
        ok &= spikes == job.expected_total
        rows.append({
            "strategy": strategy, "segments": len(descs),
            "n_ticks": job.n_ticks, "spikes": spikes,
            "sq_s": t_sq, "pll_s": t_pll, "speedup": t_sq / t_pll,
            "sq_spikes_per_s": spikes / t_sq, "pll_spikes_per_s": spikes / t_pll,
            "correct": ok,
        })
    return rows


MEGA_SIZES = (16, 12, 8)  # small = dispatch-bound: right-sized caps, no CPUs
MEGA_T_STEPS = 96
MEGA_CAPS = dict(in_cap=640, out_cap=128)  # holds the raster + AER bursts;
                                           # undersizing raises loudly


def run_megaloop(sizes=MEGA_SIZES, t_steps=MEGA_T_STEPS, seed=2):
    """Device-resident megaloop vs per-round dispatch on the small scenario.

    Same workload, same vmap backend, same check cadence — the only change
    is whether the exec+sync rounds run inside one jitted lax.while_loop
    (one host sync per dispatch) or one jitted call per round with a fused
    host-side done check every other round.  Final states must be
    bit-identical; the win is pure dispatch + sync overhead, which is why
    the scenario is the *small* hundred-round network with workload-sized
    channel caps (a CPU-free event-driven platform): per-round host
    overhead is a fixed cost, so it dominates exactly when rounds are
    cheap.  Best-of-3 runs per mode to tame container noise.
    """
    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.2, seed=seed)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster,
                                               **MEGA_CAPS)
    t_per = t_mega = float("inf")
    for _ in range(3):
        t, ctl_per = _timed(cfg, states, pending, "vmap", fused=False)
        t_per = min(t_per, t)
        t, ctl_mega = _timed(cfg, states, pending, "vmap", fused=True)
        t_mega = min(t_mega, t)
    identical = ctl_per.rounds_run == ctl_mega.rounds_run
    per_st, mega_st = ctl_per.result_states(), ctl_mega.result_states()
    for a, b in zip(jax.tree.leaves(per_st), jax.tree.leaves(mega_st)):
        identical &= bool(np.array_equal(np.asarray(a), np.asarray(b)))
    counts = snn.output_spike_counts(mega_st, meta)
    identical &= bool(np.array_equal(counts, job.expected_counts))
    per_rps = ctl_per.rounds_run / t_per
    mega_rps = ctl_mega.rounds_run / t_mega
    return {
        "rounds": ctl_mega.rounds_run,
        "per_round_s": t_per, "mega_s": t_mega,
        "per_round_rps": per_rps, "mega_rps": mega_rps,
        "speedup": mega_rps / per_rps,
        "identical": identical,
    }


TRACE_RING_CAP = 1024  # sized for the megaloop scenario (~320 events/segment
                       # per 100-round dispatch): lost=0 with 3x headroom


def run_trace_overhead(sizes=MEGA_SIZES, t_steps=MEGA_T_STEPS, seed=2):
    """Telemetry overhead on the fused megaloop — the <10% claim, measured.

    The megaloop scenario is the worst case for tracing: dispatch-bound
    rounds where every extra device op is visible.  Same workload runs
    untraced (``obs=None``, tracing compiled out) and traced
    (``obs=TraceConfig(TRACE_RING_CAP)``, rings carried in the loop state,
    drained on the existing dispatch sync), best-of-3 each; final states
    minus the ring must be bit-identical, which is what ``ok`` reports —
    the overhead ratio itself is informational (container noise swamps a
    hard threshold in CI).
    """
    from repro.obs import TraceConfig

    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.2, seed=seed)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster,
                                               **MEGA_CAPS)
    t_plain = t_traced = float("inf")
    for _ in range(3):
        t, ctl_plain = _timed(cfg, states, pending, "vmap", fused=True)
        t_plain = min(t_plain, t)
        t, ctl_traced = _timed(cfg, states, pending, "vmap", fused=True,
                               obs=TraceConfig(capacity=TRACE_RING_CAP))
        t_traced = min(t_traced, t)
    plain_st = ctl_plain.result_states()
    traced_st = dict(ctl_traced.result_states())
    traced_st.pop("trace", None)
    identical = ctl_plain.rounds_run == ctl_traced.rounds_run
    for a, b in zip(jax.tree.leaves(plain_st), jax.tree.leaves(traced_st)):
        identical &= bool(np.array_equal(np.asarray(a), np.asarray(b)))
    counts = snn.output_spike_counts(ctl_traced.result_states(), meta)
    identical &= bool(np.array_equal(counts, job.expected_counts))
    spikes = snn.total_spikes(plain_st)
    return {
        "rounds": ctl_traced.rounds_run,
        "plain_s": t_plain, "traced_s": t_traced,
        "plain_spikes_per_s": spikes / t_plain,
        "traced_spikes_per_s": spikes / t_traced,
        "overhead_pct": (t_traced / t_plain - 1.0) * 100.0,
        "events": len(ctl_traced.trace_events()),
        "lost": ctl_traced.trace_lost,
        "ring_cap": TRACE_RING_CAP,
        "identical": identical,
    }


FAULT_RATES = (0.0, 0.2, 0.5, 1.0)
FAULT_ON = dict(seed=7, p_spike_drop=0.1, p_spike_dup=0.05)


def run_faults(sizes=MEGA_SIZES, t_steps=MEGA_T_STEPS, seed=2):
    """Fault-injection overhead + the degradation curve (docs/faults.md).

    Two claims, measured on the megaloop scenario (dispatch-bound, so any
    extra per-round device work is maximally visible):

    * **overhead** — the same fused-vmap workload runs fault-free
      (``faults=None``, the subsystem compiled out) and with live transport
      faults (seeded per-spike drop/dup hashing inside the loop), best-of-3
      each; the fault-on run must also be bit-identical fused vs per-round
      (seeded determinism is part of ``ok``, and injection overhead must
      stay under 10%).
    * **degradation** — ``snn.degradation_sweep`` drives p_spike_drop
      through FAULT_RATES; fidelity must be exactly 1.0 at rate 0 (faults
      compiled out ≡ baseline) and weakly monotone in rate (the nested-CRN
      hash guarantee), within a small tolerance for integer spike counts.
    """
    from repro.faults import FaultConfig

    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.2, seed=seed)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    off = snn.build_snn(job.layers, descs, job.raster, **MEGA_CAPS)
    on = snn.build_snn(job.layers, descs, job.raster,
                       faults=FaultConfig(**FAULT_ON), **MEGA_CAPS)
    t_off = t_on = float("inf")
    for _ in range(3):
        t, ctl_off = _timed(*off[:3], "vmap", fused=True)
        t_off = min(t_off, t)
        t, ctl_on = _timed(*on[:3], "vmap", fused=True)
        t_on = min(t_on, t)
    # faults=None must stay oracle-exact …
    counts = snn.output_spike_counts(ctl_off.result_states(), off[3])
    ok = bool(np.array_equal(counts, job.expected_counts))
    # … and the faulted run bit-identical fused vs per-round (determinism)
    _, ctl_pr = _timed(*on[:3], "vmap", fused=False)
    for a, b in zip(jax.tree.leaves(ctl_on.result_states()),
                    jax.tree.leaves(ctl_pr.result_states())):
        ok &= bool(np.array_equal(np.asarray(a), np.asarray(b)))
    st = ctl_on.result_states()["stats"]
    overhead = (t_on / t_off - 1.0) * 100.0
    ok &= overhead <= 10.0

    sweep = snn.degradation_sweep(job, FAULT_RATES, fault_kind="transport",
                                  seed=FAULT_ON["seed"], **MEGA_CAPS)
    fids = [r["fidelity"] for r in sweep]
    ok &= fids[0] == 1.0
    ok &= all(fids[i] + 1e-9 >= fids[i + 1] - 0.02
              for i in range(len(fids) - 1))
    return {
        "off_s": t_off, "on_s": t_on,
        "off_rps": ctl_off.rounds_run / t_off,
        "on_rps": ctl_on.rounds_run / t_on,
        "overhead_pct": overhead,
        "rounds": ctl_on.rounds_run,
        "dropped": int(np.asarray(st["spikes_dropped"]).sum()),
        "duped": int(np.asarray(st["spikes_duped"]).sum()),
        "rates": list(FAULT_RATES), "fidelity": fids,
        "identical": ok,
    }


HYBRID_SIZES = (48, 40, 16)
HYBRID_T_STEPS = 12
HYBRID_QUANTUM = 700  # live CPUs need real instruction windows


def run_hybrid(strategies=("split", "packed", "auto"), sizes=HYBRID_SIZES,
               t_steps=HYBRID_T_STEPS, seed=5):
    """The paper's headline co-simulation scenario as a benchmark: dense
    VMM offload on CPU0's units while CPU1 injects a rate-coded raster
    into spiking layers over MMIO (CIM_REG_SPIKE) and reads the output
    counts back (CIM_REG_COUNTS), everything in one platform.

    Per platform shape (split / packed / traffic-aware auto with the
    injector pseudo-group pinned to CPU1's segment), the job runs on the
    sq and pll backends; both halves are verified — the dense O matrix
    and the CPU-published spike counts in shared DRAM against their
    oracles, spike totals across backends — while being timed.
    """
    job = snn.hybrid_job(sizes, t_steps=t_steps, rate=0.5, seed=seed)
    rows = []
    for strategy in strategies:
        cfg, states, pending, meta = snn.build_hybrid(
            job, strategy, channel_latency=2000)
        t_sq, ctl_sq = _timed(cfg, states, pending, "sequential",
                              max_rounds=800, quantum=HYBRID_QUANTUM)
        t_pll, ctl_pll = _timed(cfg, states, pending, "vmap",
                                max_rounds=800, quantum=HYBRID_QUANTUM)
        spikes = snn.total_spikes(ctl_pll.result_states())
        assert spikes == snn.total_spikes(ctl_sq.result_states()), \
            "backends disagree on spike totals"
        o, counts = snn.hybrid_results(ctl_pll.result_states(), meta)
        ok = bool(np.array_equal(o, job.dense_expected))
        ok &= bool(np.array_equal(counts, job.snn.expected_counts))
        ok &= spikes == job.snn.expected_total
        rows.append({
            "strategy": strategy, "segments": cfg.n_segments,
            "n_ticks": job.snn.n_ticks, "spikes": spikes,
            "sq_s": t_sq, "pll_s": t_pll, "speedup": t_sq / t_pll,
            "sq_spikes_per_s": spikes / t_sq,
            "pll_spikes_per_s": spikes / t_pll,
            "rounds": ctl_pll.rounds_run,
            "pll_rounds_per_s": ctl_pll.rounds_run / t_pll,
            "correct": ok,
        })
    return rows


SERVE_N_REQ = 16          # fleet size: enough for two full 8-buckets
SERVE_BUCKETS = (2, 8)    # acceptance wants >=2 bucket sizes; 8 carries
                          # the >=2x-over-solo bar
SERVE_T_STEPS = MEGA_T_STEPS  # same dispatch-bound regime as run_megaloop
SERVE_CAPS = dict(in_cap=1024, out_cap=128)  # fleet rasters are seed-varied
                                             # (up to ~413 events): headroom
                                             # over the worst draw


def run_serve(sizes=MEGA_SIZES, n_requests=SERVE_N_REQ,
              buckets=SERVE_BUCKETS, t_steps=SERVE_T_STEPS, seed=6):
    """Fleet serving: requests/sec and p99 latency per bucket size.

    A fleet of independent inference requests (same topology, different
    rasters — one normalized bucket key) is served through ``SnnServer``
    at each bucket size, against two solo-loop baselines, each running the
    requests back to back through their own ``Controller.run``:

    * **sq** — the sequential backend, the paper-convention baseline every
      other scenario in this file reports against.  The >=2x acceptance
      bar at bucket 8+ is enforced against this one (in ``ok``).
    * **pll** — the fused-vmap megaloop, the strongest single-job path.
      Reported honestly: on a single-core host the job axis does NOT beat
      it (``vs_pll`` ~0.9x) — vmapped sort/scatter rounds execute
      per-job-row on CPU, so batched compute is serial-linear and the
      dispatch amortization roughly cancels against the freeze/stack
      overhead.  The batched win over pll needs parallel hardware (the
      ``shard_map`` fan-out) or host-bound loops; what batching buys
      unconditionally is the sq/per-round orchestration overhead.

    Every served request must be bit-identical to its solo run at the
    same ``check_every`` cadence and match its oracle counts — both in
    ``ok``.  p99 is serving latency — wall time from ``submit`` to the
    request's bucket completing — so the batched p99 *rises* with bucket
    size while throughput climbs: the classic batching trade, reported
    honestly.  Warm-up runs come first so compile time lands outside the
    measured window.
    """
    from repro.serve.snn_serve import SnnServer
    from repro.snn import workloads as wl

    check_every = 4
    reqs = wl.serve_fleet(n_requests, sizes, seed=seed,
                          t_steps_choices=(t_steps,), rate=0.2,
                          **SERVE_CAPS)

    def solo_pass(backend, fused):
        lats, sts = [], []
        t0 = time.perf_counter()
        for r in reqs:
            t1 = time.perf_counter()
            c = Controller(r.cfg, r.states, r.pending, backend=backend,
                           quantum=QUANTUM)
            c.run(max_rounds=400, check_every=check_every, fused=fused)
            lats.append(time.perf_counter() - t1)
            sts.append(c.result_states())
        return time.perf_counter() - t0, lats, sts

    warm = Controller(reqs[0].cfg, reqs[0].states, reqs[0].pending,
                      backend="vmap", quantum=QUANTUM)
    warm.run(max_rounds=400, check_every=check_every, fused=True)
    warm.block_until_ready()
    pll_total = float("inf")
    for _ in range(3):
        total, lats, sts = solo_pass("vmap", True)
        if total < pll_total:
            pll_total, pll_lat, solo_states = total, lats, sts
    # sq is ~minutes-per-repeat territory and 10x+ off the pace: one pass
    warm = Controller(reqs[0].cfg, reqs[0].states, reqs[0].pending,
                      backend="sequential", quantum=QUANTUM)
    warm.run(max_rounds=400, check_every=check_every)
    sq_total, sq_lat, _ = solo_pass("sequential", None)
    sq_rps = n_requests / sq_total
    pll_rps = n_requests / pll_total

    rows = []
    for bucket in buckets:
        def serve_once():
            srv = SnnServer(quantum=QUANTUM, check_every=check_every,
                            max_rounds=400, bucket_size=bucket)
            for r in reqs:
                srv.submit(r)
            t0 = time.perf_counter()
            res = srv.flush()
            return time.perf_counter() - t0, res, srv
        serve_once()  # warm: compile the width-`bucket` batched megaloop
        t_best = float("inf")
        for _ in range(3):
            t, res, srv = serve_once()
            if t < t_best:
                t_best, best, best_srv = t, res, srv
        lats = [best[k].latency_s for k in sorted(best)]
        ok = all(r.ok for r in best.values())
        for j, k in enumerate(sorted(best)):
            r = best[k]
            ok &= bool(np.array_equal(r.output_counts(),
                                      reqs[j].expected_counts))
            for a, b in zip(jax.tree.leaves(solo_states[j]),
                            jax.tree.leaves(r.states)):
                ok &= bool(np.array_equal(np.asarray(a), np.asarray(b)))
        rps = n_requests / t_best
        if bucket >= 8:
            ok &= rps / sq_rps >= 2.0  # the acceptance bar, in-band
        rows.append({
            "bucket": bucket, "n_requests": n_requests,
            "serve_s": t_best, "req_per_s": rps,
            "p99_ms": float(np.percentile(lats, 99)) * 1e3,
            "sq_s": sq_total, "sq_req_per_s": sq_rps,
            "sq_p99_ms": float(np.percentile(sq_lat, 99)) * 1e3,
            "pll_req_per_s": pll_rps,
            "pll_p99_ms": float(np.percentile(pll_lat, 99)) * 1e3,
            "vs_sq": rps / sq_rps, "vs_pll": rps / pll_rps,
            "dispatches": best_srv.dispatches,
            "rounds": max(r.rounds for r in best.values()),
            "correct": ok,
        })
    return rows


def run_wide(sizes=WIDE_SIZES, t_steps=WIDE_T_STEPS, seed=4):
    """Naive vs spike-traffic-aware placement of a wide multi-crossbar net.

    The naive (chain-order uniform) run is also the profiling pass: its
    per-unit spike counters feed ``measure_traffic``, whose matrix drives
    the traffic-aware re-placement.  Returns one row per placement.
    """
    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.4, seed=seed)
    rows = []

    def timed_placement(name, descs, placement):
        cfg, states, pending, meta = snn.build_snn(job.layers, descs,
                                                   job.raster,
                                                   placement=placement)
        t_sq, ctl_sq = _timed(cfg, states, pending, "sequential")
        t_pll, ctl_pll = _timed(cfg, states, pending, "vmap")
        spikes = snn.total_spikes(ctl_pll.result_states())
        assert spikes == snn.total_spikes(ctl_sq.result_states()), \
            "backends disagree on spike totals"
        counts = snn.output_spike_counts(ctl_pll.result_states(), meta)
        rows.append({
            "placement": name, "segments": cfg.n_segments,
            "units": snn.n_units_for(job.layers),
            "sq_s": t_sq, "pll_s": t_pll, "spikes": spikes,
            "sq_spikes_per_s": spikes / t_sq,
            "pll_spikes_per_s": spikes / t_pll,
            "correct": bool(np.array_equal(counts, job.expected_counts)),
        })
        return ctl_pll, meta

    naive_descs = snn.segmentation_for(job.layers, "uniform", n_segments=4)
    ctl, meta = timed_placement("naive", naive_descs, None)
    _, traffic = snn.measure_traffic(ctl.result_states(), meta)
    ta_descs, ta_placement = snn.auto_segmentation_for(
        job.layers, n_segments=4, slots_per_seg=4, traffic=traffic)
    timed_placement("traffic_aware", ta_descs, ta_placement)
    return rows


def main(out=print):
    net = "x".join(str(s) for s in SIZES)
    for r in run():
        out(f"fig5snn/{r['strategy']}/{net},{r['sq_s']*1e6:.0f},"
            f"sq_vs_pll_speedup={r['speedup']:.2f}x"
            f" spikes={r['spikes']}"
            f" sq_spk_per_s={r['sq_spikes_per_s']:.0f}"
            f" pll_spk_per_s={r['pll_spikes_per_s']:.0f}"
            f" pll_rounds_per_s={r['pll_rounds_per_s']:.0f}"
            f" segments={r['segments']} ok={r['correct']}")
    rec_net = "x".join(str(s) for s in REC_SIZES)
    for r in run_recurrent():
        out(f"fig5snn/recurrent/{r['strategy']}/{rec_net},{r['sq_s']*1e6:.0f},"
            f"sq_vs_pll_speedup={r['speedup']:.2f}x"
            f" spikes={r['spikes']} n_ticks={r['n_ticks']}"
            f" sq_spk_per_s={r['sq_spikes_per_s']:.0f}"
            f" pll_spk_per_s={r['pll_spikes_per_s']:.0f}"
            f" segments={r['segments']} ok={r['correct']}")
    hy_net = "x".join(str(s) for s in HYBRID_SIZES)
    for r in run_hybrid():
        out(f"fig5snn/hybrid/{r['strategy']}/{hy_net},{r['sq_s']*1e6:.0f},"
            f"sq_vs_pll_speedup={r['speedup']:.2f}x"
            f" spikes={r['spikes']} n_ticks={r['n_ticks']}"
            f" sq_spk_per_s={r['sq_spikes_per_s']:.0f}"
            f" pll_spk_per_s={r['pll_spikes_per_s']:.0f}"
            f" pll_rounds_per_s={r['pll_rounds_per_s']:.0f}"
            f" segments={r['segments']} ok={r['correct']}")
    m = run_megaloop()
    mega_net = "x".join(str(s) for s in MEGA_SIZES)
    out(f"megaloop/vmap/{mega_net},{m['per_round_s']*1e6:.0f},"
        f"mega_rounds_per_s={m['mega_rps']:.0f}"
        f" per_round_rounds_per_s={m['per_round_rps']:.0f}"
        f" speedup={m['speedup']:.2f}x rounds={m['rounds']}"
        f" ok={m['identical']}")
    o = run_trace_overhead()
    out(trace_line(o))
    out(faults_line(run_faults()))
    for r in run_serve():
        out(serve_line(r))
    wide = run_wide()
    wide_net = "x".join(str(s) for s in WIDE_SIZES)
    base = wide[0]
    for r in wide:
        gain = r["pll_spikes_per_s"] / base["pll_spikes_per_s"]
        out(f"fig5snn/wide/{r['placement']}/{wide_net},{r['sq_s']*1e6:.0f},"
            f"pll_spk_per_s={r['pll_spikes_per_s']:.0f}"
            f" sq_spk_per_s={r['sq_spikes_per_s']:.0f}"
            f" vs_naive={gain:.2f}x spikes={r['spikes']}"
            f" segments={r['segments']} units={r['units']} ok={r['correct']}")


def trace_line(o):
    mega_net = "x".join(str(s) for s in MEGA_SIZES)
    return (f"telemetry/megaloop/{mega_net},{o['plain_s']*1e6:.0f},"
            f"traced_spk_per_s={o['traced_spikes_per_s']:.0f}"
            f" untraced_spk_per_s={o['plain_spikes_per_s']:.0f}"
            f" overhead_pct={o['overhead_pct']:.1f}"
            f" events={o['events']} lost={o['lost']}"
            f" ring_cap={o['ring_cap']} rounds={o['rounds']}"
            f" ok={o['identical']}")


def serve_line(r):
    mega_net = "x".join(str(s) for s in MEGA_SIZES)
    return (f"serve/megaloop/{mega_net}/b{r['bucket']},"
            f"{r['sq_s']*1e6:.0f},"
            f"req_per_s={r['req_per_s']:.1f}"
            f" p99_ms={r['p99_ms']:.1f}"
            f" sq_req_per_s={r['sq_req_per_s']:.2f}"
            f" sq_p99_ms={r['sq_p99_ms']:.0f}"
            f" pll_req_per_s={r['pll_req_per_s']:.1f}"
            f" vs_sq={r['vs_sq']:.2f}x vs_pll={r['vs_pll']:.2f}x"
            f" n_req={r['n_requests']} dispatches={r['dispatches']}"
            f" rounds={r['rounds']} ok={r['correct']}")


def faults_line(f):
    mega_net = "x".join(str(s) for s in MEGA_SIZES)
    fids = "/".join(f"{x:.3f}" for x in f["fidelity"])
    rates = "/".join(f"{x:g}" for x in f["rates"])
    return (f"faults/megaloop/{mega_net},{f['off_s']*1e6:.0f},"
            f"fault_on_rps={f['on_rps']:.0f}"
            f" fault_off_rps={f['off_rps']:.0f}"
            f" overhead_pct={f['overhead_pct']:.1f}"
            f" dropped={f['dropped']} duped={f['duped']}"
            f" fidelity@{rates}={fids}"
            f" rounds={f['rounds']} ok={f['identical']}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="SNN benchmark section (see benchmarks/README.md)")
    ap.add_argument("scenario", nargs="?", default="all",
                    choices=("all", "faults", "trace", "serve"),
                    help="run one scenario standalone (default: all)")
    ap.add_argument("--trace", action="store_true",
                    help="alias for the 'trace' scenario "
                         "(traced vs untraced megaloop, the <10%% claim)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any emitted line carries ok=False "
                         "(CI smoke, mirrors benchmarks/run.py --check)")
    args = ap.parse_args()
    emitted = []

    def _out(line):
        print(line)
        emitted.append(str(line))

    if args.trace or args.scenario == "trace":
        _out(trace_line(run_trace_overhead()))
    elif args.scenario == "faults":
        _out(faults_line(run_faults()))
    elif args.scenario == "serve":
        for r in run_serve():
            _out(serve_line(r))
    else:
        main(out=_out)
    if args.check:
        bad = [l for l in emitted if "ok=False" in l or "correct=False" in l]
        if bad:
            sys.exit("verification failed:\n" + "\n".join(bad))
        print(f"# verification flags clean across {len(emitted)} lines")
