"""Shared benchmark machinery: workload construction per segmentation
strategy + timed sq/pll comparison (the paper's measurement, §V)."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

FULL = os.environ.get("REPRO_FULL_BENCH", "0") == "1"
SCALE = 1 if FULL else 8  # Table III dims divided by SCALE unless FULL


def build_workload(layer: wl.Layer, strategy: str, mode: str, channel_latency: int):
    """Returns (cfg, states, pending, job, layer)."""
    if strategy == "uniform":
        descs = sg.uniform(2, 2)
        mgrs, ids = [0, 1], {0: (0, 1), 1: (2, 3)}
    elif strategy == "load_oriented":
        descs = sg.load_oriented()
        mgrs, ids = [1], {1: (0, 2)}
    else:
        raise ValueError(strategy)
    if mode == "cim":
        job = wl.cim_workload(layer, mgr_segments=mgrs, cim_ids_per_mgr=ids,
                              ordinals=sg.mailbox_ordinals(descs))
        kw = dict(programs=job["programs"], dram_words=job["dram"],
                  crossbars=job["crossbars"], scratch_init=job["scratch"])
    elif mode == "riscv":
        job = wl.riscv_workload(layer)
        kw = dict(programs=job["programs"], dram_words=job["dram"])
    elif mode == "mixed":
        # paper-style combined load: CPU0 computes a slice on RISC-V + DRAM
        # while CPU1 offloads the rest to CIM units (load-oriented: CPU1
        # drives the CIM segments; uniform: both CPUs loaded).
        cim_job = wl.cim_workload(layer, mgr_segments=mgrs[-1:], cim_ids_per_mgr=ids,
                                  ordinals=sg.mailbox_ordinals(descs))
        r_layer = wl.Layer(layer.network, layer.layer, layer.h, layer.w, max(layer.p // 2, 1))
        r_job = wl.riscv_workload(r_layer)
        job = dict(cim_job)
        job["programs"] = {**cim_job["programs"], 0: r_job["programs"][0]}
        kw = dict(programs=job["programs"], dram_words=job["dram"],
                  crossbars=job["crossbars"], scratch_init=job["scratch"])
    else:
        raise ValueError(mode)
    cfg, states, pending = sg.build(descs, channel_latency=channel_latency, **kw)
    return cfg, states, pending, job


def timed_run(cfg, states, pending, backend: str, quantum: int, max_rounds=2000,
              fused=None):
    """Warm-compile, then run to completion; returns (host_s, sim_cycles, ctl).

    ``fused`` is forwarded to ``Controller.run`` (None = backend default:
    the device-resident megaloop on vmap/shard_map, the per-round host loop
    on sequential/threads).  Rounds/sec is ``ctl.rounds_run / host_s``.
    """
    warm = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    warm.run(max_rounds=2, check_every=2, fused=fused)  # compile round + megastep
    warm.block_until_ready()
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    t0 = time.perf_counter()
    rounds, _ = ctl.run(max_rounds=max_rounds, check_every=2, fused=fused)
    host = time.perf_counter() - t0
    return host, int(np.max(ctl.sim_time())), ctl


def verify(ctl, job, layer) -> bool:
    st = ctl.result_states()
    o = np.asarray(
        st["dram"]["data"][0][job["o_word"] : job["o_word"] + layer.h * layer.p]
    ).reshape(layer.h, layer.p)
    return bool(np.array_equal(o, job["expected"]))
