"""End-to-end LM training on the framework's data/optimizer/checkpoint
substrate (any of the 10 assigned architectures via --arch; reduced configs
by default so this runs in minutes on CPU).

  PYTHONPATH=src python examples/train_lm.py --arch zamba2-2.7b --steps 120
  PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --layers 4 \
      --d-model 256 --steps 300 --ckpt-dir /tmp/ckpt

Fault-tolerance demo (crash + auto-resume):
  PYTHONPATH=src python examples/train_lm.py --ckpt-dir /tmp/ft --fail-at-step 60
  PYTHONPATH=src python examples/train_lm.py --ckpt-dir /tmp/ft
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--steps", "120", "--batch", "8", "--seq", "128"])
