"""Rate-coded digit classification on a 2-segment neuromorphic VP.

The VP's second programming model: instead of streaming dense vectors into
the CIM crossbars, the crossbars run in *spike mode* — synapse matrices
integrating address-event (AER) spikes into LIF membrane potentials, with
inter-layer spikes crossing segment boundaries through the same
time-decoupled channels the dense benchmarks use.

A 2-layer network classifies 8×8 digit glyphs: layer 1's synapses are
template correlators (+4 on template pixels, −1 off), layer 2 amplifies the
winning class.  The input glyph is rate-coded into a Bernoulli spike train;
the class whose output neuron spikes most wins.  The run is verified
bit-exactly against the pure-jnp SNN oracle.

  PYTHONPATH=src python examples/snn_inference.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro import snn
from repro.core.controller import Controller

GLYPHS = {  # 8x8 digit templates
    0: ["..####..", ".#....#.", "#......#", "#......#",
        "#......#", "#......#", ".#....#.", "..####.."],
    1: ["...##...", "..###...", "...##...", "...##...",
        "...##...", "...##...", "...##...", ".######."],
    7: ["########", "......##", ".....##.", "....##..",
        "...##...", "..##....", ".##.....", "##......"],
}


def glyph_rates(rows, noise_rng=None, flip=0.05):
    x = np.array([[c == "#" for c in r] for r in rows], float).reshape(-1)
    if noise_rng is not None:
        flips = noise_rng.random(64) < flip
        x = np.where(flips, 1.0 - x, x)
    return x * 0.8 + 0.1  # on-pixels spike at 0.9, off at 0.1


classes = sorted(GLYPHS)
templates = np.stack([glyph_rates(GLYPHS[c]) > 0.5 for c in classes])  # (3, 64)

# layer 1: template correlators; layer 2: diagonal amplifier
w1 = np.where(templates, 4, -1).astype(np.int8)  # (3, 64)
w2 = (np.eye(len(classes)) * 8).astype(np.int8)
layers = [
    snn.SNNLayer(w1, snn.LIFParams(thresh=60, leak=1)),
    snn.SNNLayer(w2, snn.LIFParams(thresh=8, leak=0)),
]

T_STEPS = 24
rng = np.random.default_rng(7)
descs = snn.segmentation_for(len(layers), "uniform", n_segments=2)
print(f"2-segment VP, one spike-mode CIM unit per segment; {T_STEPS}-step rate code\n")
print(f"{'digit':>6s}{'output spike counts':>28s}{'predicted':>11s}{'oracle ok':>11s}")

for digit in classes:
    raster = snn.rate_encode(glyph_rates(GLYPHS[digit], rng), T_STEPS,
                             seed=100 + digit)
    expected, _ = snn.oracle_run(layers, raster)
    cfg, states, pending, meta = snn.build_snn(layers, descs, raster)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    ctl.run(max_rounds=200, check_every=1)
    counts = snn.output_spike_counts(ctl.result_states(), meta)
    pred = classes[int(np.argmax(counts))]
    ok = bool(np.array_equal(counts, expected))
    mark = "✓" if pred == digit else "✗"
    print(f"{digit:>6d}{str(counts.tolist()):>28s}{pred:>9d} {mark}{str(ok):>10s}")

from repro.core import channel as ch

print("\nAER traffic histogram bin (MSG_SPIKE):",
      int(ctl.stats()["txn_histogram"][ch.MSG_SPIKE]), "spike events routed in last run")
