"""Quickstart: the paper in ninety seconds.

Builds the uniform-segmentation VP (2 segments × {RISC-V CPU, 2 CIM-Units},
shared DRAM), runs a GoogleNet conv layer's VMM both on the RISC-V core and
offloaded to the memristor crossbars, and compares conventional sequential
SystemC-style execution against the time-decoupled parallel backend.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # for benchmarks.*

import numpy as np

from benchmarks.common import build_workload, timed_run, verify
from repro.vp import workloads as wl

layer = wl.TABLE_III[2].scaled(3)  # ImageNet-conv1, reduced for CPU (÷3 keeps compute ≫ sync overhead)
print(f"workload: {layer.name}  O[{layer.h},{layer.p}] = A[{layer.h},{layer.w}] @ B[{layer.w},{layer.p}]\n")

print("1) RISC-V + shared DRAM (the von Neumann path)")
cfg, states, pending, job = build_workload(layer, "uniform", "riscv", 10_000)
host, cycles, ctl = timed_run(cfg, states, pending, "vmap", 10_000)
print(f"   simulated cycles: {cycles:,}   result correct: {verify(ctl, job, layer)}")
riscv_cycles = cycles

print("2) offloaded to CIM-Units (computing-in-memory)")
cfg, states, pending, job = build_workload(layer, "uniform", "cim", 10_000)
host_sq, cycles, ctl = timed_run(cfg, states, pending, "sequential", 10_000)
print(f"   simulated cycles: {cycles:,}   ({riscv_cycles / cycles:.1f}x fewer than RISC-V)")
print(f"   result correct: {verify(ctl, job, layer)}")

print("3) parallel simulation speedup (the paper's contribution)")
host_pll, _, ctl = timed_run(cfg, states, pending, "vmap", 10_000)
print(f"   sequential host time: {host_sq*1e3:7.1f} ms  (one segment after another)")
print(f"   parallel   host time: {host_pll*1e3:7.1f} ms  (segments stepped together)")
print(f"   => simulation speedup: {host_sq / host_pll:.2f}x  (paper: up to 2.3x uniform)")
print("\ntransaction histogram (Fig. 1a tracing):", np.asarray(ctl.stats()["txn_histogram"]))
