"""Fleet-scale SNN serving through one batched megaloop.

A fleet of independent inference requests (same compiled topology,
per-request rasters, weights, and channel caps) is submitted to
``SnnServer`` and served in padded buckets: each bucket runs as ONE
jitted job-axis megaloop dispatch (docs/serving.md), with per-job
termination flags judging every request against its OWN caps.

The script serves the same fleet at two bucket sizes, verifies every
result against the pure-jnp oracle counts carried by the request
builder, spot-checks that heterogeneous caps really shared one bucket,
and writes a requests/sec + p99-latency artifact, schema-validated
before exit so CI can trust its shape:

  PYTHONPATH=src python examples/snn_serve.py --json serve_bench.json

p99 here is *serving* latency — wall time from ``submit`` to the
request's bucket completing — so it rises with bucket size while
throughput climbs: the batching trade, visible in one artifact.
"""
import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.serve.snn_serve import SnnServer, _normalize
from repro.snn import workloads as wl

SIZES = (16, 12, 8)
T_STEPS = 8
QUANTUM = 32
N_REQUESTS = 8
BUCKETS = (2, 8)

# the artifact contract: (key, required type) per row — checked by
# validate_artifact so downstream dashboards can rely on the shape
ROW_SCHEMA = (("bucket", int), ("req_per_s", float), ("p99_ms", float),
              ("served", int), ("dispatches", int), ("all_ok", bool))


def validate_artifact(obj):
    assert isinstance(obj.get("job"), str) and isinstance(obj.get("seed"), int)
    assert isinstance(obj.get("n_requests"), int) and obj["n_requests"] > 0
    assert isinstance(obj.get("check_every"), int)
    rows = obj.get("rows")
    assert isinstance(rows, list) and rows, "rows must be a non-empty list"
    for row in rows:
        for key, typ in ROW_SCHEMA:
            assert isinstance(row.get(key), typ), (key, row.get(key))
        assert row["bucket"] >= 1 and row["req_per_s"] > 0
        assert row["p99_ms"] > 0 and row["served"] == obj["n_requests"]
        assert row["all_ok"], "a served request failed verification"
    assert [r["bucket"] for r in rows] == sorted(r["bucket"] for r in rows), \
        "rows must be bucket-ordered"


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve an SNN request fleet through the batched "
                    "megaloop; write a requests/sec + p99 artifact.")
    ap.add_argument("--json", metavar="PATH", default="serve_bench.json",
                    help="serving-metrics artifact output path")
    ap.add_argument("--requests", type=int, default=N_REQUESTS,
                    help="fleet size")
    ap.add_argument("--seed", type=int, default=11, help="fleet PRNG seed")
    args = ap.parse_args(argv)

    # heterogeneous caps on purpose: half the fleet gets roomier channels,
    # yet _normalize folds caps out of the bucket key, so ONE bucket serves
    # both halves (each judged against its own caps by the vmapped flags)
    fleet = (wl.serve_fleet(args.requests // 2, SIZES, seed=args.seed,
                            t_steps_choices=(T_STEPS,), in_cap=192,
                            out_cap=64)
             + wl.serve_fleet(args.requests - args.requests // 2, SIZES,
                              seed=args.seed + 1,
                              t_steps_choices=(T_STEPS,), in_cap=320,
                              out_cap=128))
    assert len({_normalize(r.cfg) for r in fleet}) == 1, \
        "mixed caps should share one bucket key"
    print(f"fleet: {len(fleet)} requests, {SIZES} @ t={T_STEPS}, "
          "mixed in_cap 192/320 -> one bucket key")

    rows = []
    for bucket in BUCKETS:
        def serve():
            srv = SnnServer(quantum=QUANTUM, check_every=4, max_rounds=400,
                            bucket_size=bucket)
            for r in fleet:
                srv.submit(r)
            t0 = time.perf_counter()
            res = srv.flush()
            return time.perf_counter() - t0, res, srv
        serve()  # warm: compile the width-`bucket` batched megaloop
        elapsed, results, srv = serve()

        all_ok = True
        for ticket, req in enumerate(fleet):
            r = results[ticket]
            assert r.ok, f"request {ticket} failed: {r.error}"
            np.testing.assert_array_equal(r.output_counts(),
                                          req.expected_counts)
            all_ok &= r.ok
        p99 = float(np.percentile([r.latency_s for r in results.values()],
                                  99)) * 1e3
        rps = len(fleet) / elapsed
        rows.append({"bucket": bucket, "req_per_s": rps, "p99_ms": p99,
                     "served": srv.served, "dispatches": srv.dispatches,
                     "all_ok": bool(all_ok)})
        print(f"bucket={bucket}: {rps:.1f} req/s, p99 {p99:.0f} ms, "
              f"{srv.dispatches} dispatches, all {srv.served} requests "
              "oracle-exact")

    artifact = {
        "job": "x".join(str(s) for s in SIZES) + f"@t{T_STEPS}",
        "seed": args.seed,
        "n_requests": len(fleet),
        "check_every": 4,
        "quantum": QUANTUM,
        "rows": rows,
    }
    validate_artifact(artifact)
    with open(args.json, "w") as f:
        json.dump(artifact, f, indent=2)
    print(f"serving metrics -> {args.json} (schema-valid)")


if __name__ == "__main__":
    main()
