"""Winner-take-all decision making on a recurrent neuromorphic VP.

Recurrent connectivity is what real neuromorphic workloads are made of
(TrueNorth/RANC cores): this example runs a two-layer *cyclic* network —
an Elman-style self-recurrent evidence layer feeding a winner-take-all
output pool whose lateral inhibition silences every neuron but the
winner, plus a feedback edge that lets the emerging decision bias the
evidence layer one tick later.  All three cyclic paths ride the same
tick-bucketed AER machinery as feed-forward spikes (one tick of axonal
delay per hop, wherever the edge points), and the run is verified
bit-exactly against the cycle-aware pure-jnp oracle over the shared tick
horizon.

  PYTHONPATH=src python examples/snn_recurrent.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro import snn
from repro.core.controller import Controller

N_IN, N_EVID, N_CLASSES = 24, 16, 4
T_STEPS = 16
SETTLE = 6  # extra ticks for the WTA competition to ring down
N_TICKS = T_STEPS + 2 + SETTLE

rng = np.random.default_rng(5)

# evidence layer: neuron j accumulates the input block of class j % 4
# (+3 on its own block, light noise elsewhere); mild random
# self-recurrence keeps evidence reverberating after the input fades
blk = N_IN // N_CLASSES
w_evid = rng.integers(-1, 1, (N_EVID, N_IN)).astype(np.int8)
for j in range(N_EVID):
    c = j % N_CLASSES
    w_evid[j, c * blk:(c + 1) * blk] = 3
evid_lateral = rng.integers(-1, 2, (N_EVID, N_EVID)).astype(np.int8)
evidence = snn.SNNLayer(w_evid, snn.LIFParams(thresh=2 * blk, leak=1),
                        lateral=evid_lateral)

# output pool: class templates + winner-take-all lateral inhibition
w_out = np.zeros((N_CLASSES, N_EVID), np.int8)
for c in range(N_CLASSES):
    w_out[c, c::N_CLASSES] = 6  # every 4th evidence neuron votes for class c
wta = (-8 * (1 - np.eye(N_CLASSES, dtype=np.int64))).astype(np.int8)
output = snn.SNNLayer(w_out, snn.LIFParams(thresh=10, leak=0), lateral=wta)

# the decision feeds back: the leading class excites its own evidence
fb = np.zeros((N_EVID, N_CLASSES), np.int8)
for c in range(N_CLASSES):
    fb[c::N_CLASSES, c] = 2
edges = (snn.RecurrentEdge(src=1, dst=0, weights=fb),)

layers = [evidence, output]
descs = snn.segmentation_for(layers, "uniform", n_segments=2, edges=edges)
print(f"2-segment VP, cyclic net: {N_EVID}-neuron Elman evidence layer, "
      f"{N_CLASSES}-way WTA output, feedback edge; horizon {N_TICKS} ticks\n")
print(f"{'stimulus':>9s}{'output spike counts':>24s}{'winner':>8s}{'oracle ok':>11s}")

for stim in range(N_CLASSES):
    # stimulate the input block that favors class `stim`
    x = np.full(N_IN, 0.15)
    x[stim * (N_IN // N_CLASSES):(stim + 1) * (N_IN // N_CLASSES)] = 0.9
    raster = snn.rate_encode(x, T_STEPS, seed=100 + stim)
    counts, totals = snn.oracle_run(layers, raster, edges=edges, n_ticks=N_TICKS)

    cfg, states, pending, meta = snn.build_snn(
        layers, descs, raster, edges=edges, n_ticks=N_TICKS)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    ctl.run(max_rounds=400, check_every=2)
    got = snn.output_spike_counts(ctl.result_states(), meta)
    ok = np.array_equal(got, counts)
    winner = int(np.argmax(got))
    marker = "*" if winner == stim else "!"
    print(f"{stim:>9d}{str(got.tolist()):>24s}{winner:>7d}{marker}"
          f"{'yes' if ok else 'NO':>11s}")
    assert ok, "VP must match the cycle-aware oracle bit-exactly"

print("\nevery run verified bit-exactly against the cycle-aware jnp oracle")
