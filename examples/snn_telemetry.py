"""Device-resident telemetry on the hybrid co-simulation, end to end.

The paper's headline scenario — dense VMM offload on CPU0's units while
CPU1 injects a spike raster over MMIO into LIF layers — runs on the fused
vmap megaloop with trace rings enabled (``Controller(obs=TraceConfig())``).
Every dispatch drains its ring batch through the ``on_telemetry`` callback
(streamed here as NDJSON, the live-dashboard format), and at the end the
full event log is exported as a Chrome-trace/Perfetto JSON timeline:
quantum slices per segment, LIF tick instants per CIM unit, inbox
occupancy counters, and cross-segment spike flow arrows.

Tracing must be *invisible* to the simulation, so the script also runs the
same job untraced and asserts the final states are bit-identical — plus
the usual oracle checks on both the dense output matrix and the
CPU-published spike counts.

  PYTHONPATH=src python examples/snn_telemetry.py --json trace.json --ndjson trace.ndjson

Load the JSON at https://ui.perfetto.dev (or chrome://tracing); see
docs/observability.md for the event schema and track layout.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import snn
from repro.core.controller import Controller
from repro.obs import TraceConfig, export

SIZES = (16, 12, 8)
T_STEPS = 6
QUANTUM = 400


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Hybrid co-simulation with device-resident telemetry: "
                    "stream NDJSON per dispatch, export a Perfetto timeline.")
    ap.add_argument("--json", metavar="PATH", default="telemetry_trace.json",
                    help="Chrome-trace/Perfetto JSON output path")
    ap.add_argument("--ndjson", metavar="PATH", default=None,
                    help="also stream drained batches here as NDJSON "
                         "(one flat object per trace event)")
    args = ap.parse_args(argv)

    job = snn.hybrid_job(SIZES, t_steps=T_STEPS, rate=0.5, seed=2)
    cfg, states, pending, meta = snn.build_hybrid(job, "packed",
                                                  channel_latency=2000)

    # untraced reference: tracing is compiled out entirely with obs=None
    ref = Controller(cfg, states, pending, backend="vmap", quantum=QUANTUM)
    ref.run(max_rounds=800, check_every=2, fused=True)

    ndjson_fh = open(args.ndjson, "w") if args.ndjson else None
    on_telemetry = export.ndjson_callback(ndjson_fh) if ndjson_fh else None
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=QUANTUM,
                     obs=TraceConfig())
    ctl.run(max_rounds=800, check_every=2, fused=True,
            on_telemetry=on_telemetry)
    if ndjson_fh:
        ndjson_fh.close()

    # bit-identity: telemetry must not perturb the simulation
    traced_st = dict(ctl.result_states())
    traced_st.pop("trace", None)
    assert ctl.rounds_run == ref.rounds_run
    for a, b in zip(jax.tree.leaves(traced_st),
                    jax.tree.leaves(ref.result_states())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # oracle checks: both halves of the co-simulation are exact
    o, counts = snn.hybrid_results(ctl.result_states(), meta)
    np.testing.assert_array_equal(o, job.dense_expected)
    np.testing.assert_array_equal(counts, job.snn.expected_counts)

    events = ctl.trace_events()
    obj = export.write_chrome_trace(args.json, events,
                                    tick_period=cfg.snn_tick_period,
                                    title="hybrid co-simulation")
    kinds = {str(k): int(n) for k, n in zip(
        *np.unique([export.tr.KIND_NAMES[int(k)] for k in events["kind"]],
                   return_counts=True))}
    print(f"rounds: {ctl.rounds_run} (bit-identical to untraced run)")
    print(f"dispatch host syncs: {ctl.dispatch_syncs} "
          f"for {ctl.dispatches} fused dispatch(es)")
    print(f"trace events: {len(events)} ({kinds}), lost: {ctl.trace_lost}")
    print(f"perfetto timeline -> {args.json} "
          f"({len(obj['traceEvents'])} trace events, schema-valid)")
    if args.ndjson:
        print(f"ndjson stream -> {args.ndjson}")
    print("dense O matrix and CPU-published spike counts match their oracles")


if __name__ == "__main__":
    main()
