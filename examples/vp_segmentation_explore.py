"""Design-space exploration of VP segmentation strategies — the workflow the
paper's VP exists to enable (§IV-C), including the automatic segmentation it
lists as future work.

For one workload, compares uniform / load-oriented / auto partitions on
simulated cycles AND host simulation time, sequential vs parallel.

  PYTHONPATH=src python examples/vp_segmentation_explore.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # for benchmarks.*

import numpy as np

from benchmarks.common import build_workload, timed_run, verify
from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

layer = wl.TABLE_III[2].scaled(8)  # ImageNet-conv1
print(f"workload: {layer.name} ({layer.h}x{layer.w}x{layer.p}), mode: cim offload\n")
print(f"{'strategy':16s}{'segments':>9s}{'sq ms':>10s}{'pll ms':>10s}{'speedup':>9s}{'cycles':>12s}{'ok':>4s}")

for strategy in ("uniform", "load_oriented"):
    cfg, states, pending, job = build_workload(layer, strategy, "cim", 10_000)
    t_sq, cyc, ctl = timed_run(cfg, states, pending, "sequential", 10_000)
    t_pll, _, ctl_p = timed_run(cfg, states, pending, "vmap", 10_000)
    ok = verify(ctl_p, job, layer)
    print(f"{strategy:16s}{cfg.n_segments:9d}{t_sq*1e3:10.1f}{t_pll*1e3:10.1f}"
          f"{t_sq/t_pll:8.2f}x{cyc:12,}{'Y' if ok else 'N':>4s}")

# automatic segmentation (paper future work): balance measured module costs
costs = {"cpu0": 3.0, "cpu1": 8.0, "dram": 2.0, "cim0": 4.0, "cim1": 4.0, "cim2": 4.0, "cim3": 4.0}
descs = sg.auto_segmentation(costs, n_segments=4)
print(f"\nauto_segmentation({costs}) ->")
for i, d in enumerate(descs):
    print(f"  segment {i}: cpu={d.cpu} dram={d.dram} cims={d.n_cims} mgr={d.cim_mgr}")
