"""Fault injection & graceful degradation on an SNN inference job.

Three passes over the same rate-coded network (see docs/faults.md):

1. **Seeded faults, traced** — the job runs fault-free (``faults=None``,
   the subsystem compiled out) and again with all three fault families
   live (stuck crossbar cells, dead/drifted neurons, seeded AER spike
   drop/duplication) plus trace rings, so every transport injection lands
   in the event log as a ``fault_injected`` event.  The fault-free run is
   asserted oracle-exact; the faulted run is asserted *deterministic*
   (bit-identical fused vs per-round dispatch).

2. **Graceful degradation** — the same faulted network is rebuilt with an
   undersized outbox and ``on_overflow="drop"``: where the default policy
   aborts with a watermark RuntimeError, the drop policy completes with
   the overflow converted into counted, traced spike loss.

3. **Degradation sweep** — ``snn.degradation_sweep`` drives one fault axis
   (transport / crossbar / neuron) through a rate grid and writes the
   accuracy-vs-fault-rate curve as a JSON artifact, schema-validated
   before the script exits so CI can trust its shape.

  PYTHONPATH=src python examples/snn_faults.py --json faults_sweep.json

"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import snn
from repro.core.controller import Controller
from repro.faults import FaultConfig, fidelity
from repro.obs import TraceConfig

SIZES = (32, 24, 10)
T_STEPS = 8
QUANTUM = 32

FAULTS = FaultConfig(seed=7, p_stuck0=0.05, p_dead=0.05,
                     p_thresh_drift=0.1, p_spike_drop=0.1, p_spike_dup=0.05)

# the sweep artifact contract: (key, required type) per row — checked by
# validate_artifact so downstream dashboards can rely on the shape
ROW_SCHEMA = (("rate", float), ("fidelity", float),
              ("total_spikes", int), ("rounds", int), ("counts", list))


def validate_artifact(obj):
    assert isinstance(obj.get("job"), str) and isinstance(obj.get("seed"), int)
    assert obj.get("fault_kind") in ("transport", "crossbar", "neuron")
    rows = obj.get("sweep")
    assert isinstance(rows, list) and rows, "sweep must be a non-empty list"
    for row in rows:
        for key, typ in ROW_SCHEMA:
            assert isinstance(row.get(key), typ), (key, row.get(key))
        assert 0.0 <= row["rate"] <= 1.0 and 0.0 <= row["fidelity"] <= 1.0
        assert all(isinstance(c, int) for c in row["counts"])
    rates = [r["rate"] for r in rows]
    assert rates == sorted(rates), "rows must be rate-ordered"
    assert rows[0]["rate"] == 0.0 and rows[0]["fidelity"] == 1.0, \
        "rate 0 must be oracle-exact (faults compiled out)"


def run(cfg, states, pending, fused=True, obs=None):
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=QUANTUM,
                     obs=obs)
    ctl.run(max_rounds=400, check_every=2, fused=fused)
    return ctl


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Seeded fault injection, graceful overflow degradation, "
                    "and an accuracy-vs-fault-rate sweep artifact.")
    ap.add_argument("--json", metavar="PATH", default="faults_sweep.json",
                    help="degradation-sweep artifact output path")
    ap.add_argument("--kind", default="transport",
                    choices=("transport", "crossbar", "neuron"),
                    help="which fault axis the sweep drives")
    ap.add_argument("--rates", default="0,0.2,0.5,1.0",
                    help="comma-separated fault rates for the sweep")
    ap.add_argument("--seed", type=int, default=7, help="fault PRNG seed")
    args = ap.parse_args(argv)

    job = snn.snn_inference_job(SIZES, t_steps=T_STEPS, rate=0.5, seed=2)
    descs = snn.segmentation_for(snn.n_units_for(job.layers), "uniform",
                                 n_segments=2)

    # -- 1. fault-free vs faulted, traced ---------------------------------
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
    base = run(cfg, states, pending)
    counts = snn.output_spike_counts(base.result_states(), meta)
    np.testing.assert_array_equal(counts, job.expected_counts)
    print(f"fault-free: {int(np.asarray(counts).sum())} output spikes, "
          "oracle-exact")

    fcfg, fstates, fpending, fmeta = snn.build_snn(
        job.layers, descs, job.raster, faults=FAULTS)
    faulted = run(fcfg, fstates, fpending, obs=TraceConfig())
    per_round = run(fcfg, fstates, fpending, fused=False)
    traced_st = dict(faulted.result_states())
    traced_st.pop("trace", None)
    for a, b in zip(jax.tree.leaves(traced_st),
                    jax.tree.leaves(per_round.result_states())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st = faulted.result_states()["stats"]
    events = faulted.trace_events()
    from repro.obs import trace as tr
    n_fault_ev = int((np.asarray(events["kind"]) == tr.EV_FAULT).sum())
    fcounts = snn.output_spike_counts(faulted.result_states(), fmeta)
    print(f"faulted:    {int(np.asarray(fcounts).sum())} output spikes "
          f"(dropped={int(np.asarray(st['spikes_dropped']).sum())}, "
          f"duped={int(np.asarray(st['spikes_duped']).sum())}), "
          f"{n_fault_ev} fault_injected trace events, "
          "bit-identical fused vs per-round")

    # -- 2. graceful degradation under an undersized outbox ---------------
    try:
        run(*snn.build_snn(job.layers, descs, job.raster, out_cap=8)[:3])
        raise AssertionError("undersized outbox should have aborted")
    except RuntimeError as e:
        print(f"raise policy: {str(e).splitlines()[0][:72]}…")
    dcfg, dstates, dpending, dmeta = snn.build_snn(
        job.layers, descs, job.raster, out_cap=8,
        faults=FaultConfig(on_overflow="drop"))
    degraded = run(dcfg, dstates, dpending)
    lost = int(np.asarray(
        degraded.result_states()["stats"]["outbox_lost"]).sum())
    dc = snn.output_spike_counts(degraded.result_states(), dmeta)
    print(f"drop policy:  run completes, {lost} spikes lost to overflow, "
          f"fidelity {fidelity(dc, job.expected_counts):.3f}")

    # -- 3. degradation sweep artifact ------------------------------------
    rates = [float(r) for r in args.rates.split(",")]
    sweep = snn.degradation_sweep(job, rates, fault_kind=args.kind,
                                  seed=args.seed)
    artifact = {
        "job": "x".join(str(s) for s in SIZES) + f"@t{T_STEPS}",
        "fault_kind": args.kind,
        "seed": args.seed,
        "sweep": [{"rate": r["rate"], "fidelity": r["fidelity"],
                   "total_spikes": r["total_spikes"], "rounds": r["rounds"],
                   "counts": [int(c) for c in r["counts"]]} for r in sweep],
    }
    validate_artifact(artifact)
    with open(args.json, "w") as f:
        json.dump(artifact, f, indent=2)
    curve = " ".join(f"{r['rate']:g}:{r['fidelity']:.3f}"
                     for r in artifact["sweep"])
    print(f"degradation sweep ({args.kind}) -> {args.json} "
          f"(schema-valid): {curve}")


if __name__ == "__main__":
    main()
