"""Wide multi-crossbar SNN layers: row-stripe sharding, column groups, and
spike-traffic-aware placement.

The headline property extends PR 1's invariant to layers that do not fit
one 256×256 crossbar: a layer sharded across k CIM units — output neurons
striped across placeable units, fan-in column tiles co-located as a charge
group — produces spike counts *bit-identical* to the unsharded pure-jnp
oracle, for every segmentation strategy, every controller backend, every
quantum, and both LIF execution paths (jnp ref and Pallas kernel).
"""
import numpy as np
import pytest

from repro import snn
from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp.cim import XBAR


def _run_vp(job, descs, placement=None, backend="vmap", quantum=32,
            use_kernel=False, max_rounds=400):
    cfg, states, pending, meta = snn.build_snn(
        job.layers, descs, job.raster, placement=placement,
        use_kernel=use_kernel)
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    ctl.run(max_rounds=max_rounds, check_every=1)
    return cfg, ctl, meta


# ---------------------------------------------------------------------------
# tiling geometry


def test_tiling_shapes():
    layers = snn.random_snn((128, 600, 520, 16), seed=0)
    groups = snn.layer_groups(layers)
    # 600 out -> 3 stripes of (256, 256, 88) rows, 1 tile each (128 fan-in);
    # 520 out / 600 in -> 3 stripes x 3 column tiles; 16 out / 520 in -> 1x3
    assert [(g.layer, g.stripe, g.n_rows, g.width) for g in groups] == [
        (0, 0, 256, 1), (0, 1, 256, 1), (0, 2, 88, 1),
        (1, 0, 256, 3), (1, 1, 256, 3), (1, 2, 8, 3),
        (2, 0, 16, 3),
    ]
    assert snn.n_units_for(layers) == 15
    for g in groups:
        assert sum(c1 - c0 for c0, c1 in g.col_edges) == layers[g.layer].n_in
        assert all(c1 - c0 <= XBAR for c0, c1 in g.col_edges)


def test_narrow_layers_are_single_units():
    layers = snn.random_snn((64, 48, 10), seed=1)  # two (out, in) layers
    groups = snn.layer_groups(layers)
    assert [g.width for g in groups] == [1] * len(layers)
    assert snn.n_units_for(layers) == len(layers)


# ---------------------------------------------------------------------------
# acceptance: 256 -> 600 across >= 3 units, every strategy x backend


WIDE_JOB = snn.snn_inference_job((256, 600), t_steps=6, rate=0.4, seed=2)


@pytest.mark.parametrize("strategy", ["uniform", "load_oriented", "auto"])
def test_wide_output_layer_matches_oracle(strategy):
    """A 256→600 layer shards across 3 CIM units; per-neuron output spike
    counts merged by global neuron id equal the unsharded oracle."""
    if strategy == "auto":
        descs, placement = snn.auto_segmentation_for(WIDE_JOB.layers,
                                                     n_segments=3)
    else:
        descs = snn.segmentation_for(WIDE_JOB.layers, strategy, n_segments=4)
        placement = None
    cfg, ctl, meta = _run_vp(WIDE_JOB, descs, placement)
    units = {u for info in meta["groups"] for u in info["units"]}
    assert len(units) >= 3, "600 neurons must occupy >= 3 crossbars"
    got = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(got, WIDE_JOB.expected_counts)
    assert snn.total_spikes(ctl.result_states()) == WIDE_JOB.expected_total


def test_wide_output_backends_bit_identical():
    descs = snn.segmentation_for(WIDE_JOB.layers, "uniform", n_segments=4)
    res = {}
    for backend in ("sequential", "vmap", "threads"):
        cfg, ctl, meta = _run_vp(WIDE_JOB, descs, backend=backend)
        st = ctl.result_states()
        res[backend] = (np.asarray(st["cims"]["spike_counts"]),
                        np.asarray(st["cims"]["v"]),
                        np.asarray(st["cims"]["ticks"]))
    for backend in ("vmap", "threads"):
        for a, b in zip(res["sequential"], res[backend]):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# column groups: fan-in beyond one crossbar's columns


FANIN_JOB = snn.snn_inference_job((96, 600, 32), t_steps=5, rate=0.4, seed=5)


def test_column_group_matches_oracle():
    """600-wide fan-in tiles into a co-located 3-slot column group whose
    owner integrates the summed charge — bit-identical to the oracle."""
    descs = snn.segmentation_for(FANIN_JOB.layers, "uniform", n_segments=3)
    cfg, ctl, meta = _run_vp(FANIN_JOB, descs)
    assert cfg.snn_grouped
    wide = meta["groups"][-1]
    assert wide["group"].width == 3
    assert len({seg for seg, _ in wide["units"]}) == 1, "group co-located"
    got = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(got, FANIN_JOB.expected_counts)
    assert snn.total_spikes(ctl.result_states()) == FANIN_JOB.expected_total


def test_column_group_kernel_path_matches_ref_path():
    """use_kernel=True routes the group-reduced tick through the Pallas
    kernel's extra-charge input; results stay bit-identical."""
    descs = snn.segmentation_for(FANIN_JOB.layers, "uniform", n_segments=3)
    outs = []
    for use_kernel in (False, True):
        cfg, ctl, meta = _run_vp(FANIN_JOB, descs, use_kernel=use_kernel)
        outs.append(snn.output_spike_counts(ctl.result_states(), meta))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], FANIN_JOB.expected_counts)


def test_split_placement_of_column_group_rejected():
    """A column group must not straddle segments (the charge reduction is
    tick-atomic only inside one segment)."""
    descs = [sg.SegmentDesc(cpu=True, dram=True, n_cims=2, cim_mgr=0),
             sg.SegmentDesc(n_cims=4, cim_mgr=0)]
    layers = snn.random_snn((300, 32), seed=3)  # one stripe x 2 col tiles
    raster = snn.rate_encode(np.full(300, 0.5), 4, seed=4)
    with pytest.raises(AssertionError, match="co-located"):
        snn.build_snn(layers, descs, raster, placement=[1])  # units 1..2 straddle


# ---------------------------------------------------------------------------
# the sharding property: random k, segmentation, backend -> oracle-exact


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_wide_sharding_property(seed):
    """Randomized draw of layer sizes (wide in both dimensions), placement
    strategy, backend, and quantum: VP spike counts are bit-identical to
    the unsharded oracle in every draw."""
    rng = np.random.default_rng(100 + seed)
    sizes = (int(rng.integers(16, 128)),
             int(rng.integers(XBAR + 1, 3 * XBAR)),  # forces 2-3 stripes
             int(rng.integers(8, 48)))
    t_steps = int(rng.integers(3, 7))
    job = snn.snn_inference_job(sizes, t_steps=t_steps, rate=0.45, seed=seed)
    strategy = rng.choice(["uniform", "load_oriented", "auto", "auto_traffic"])
    if strategy == "auto_traffic":
        _, traffic = snn.profile_traffic(job.layers, job.raster)
        descs, placement = snn.auto_segmentation_for(
            job.layers, n_segments=4, slots_per_seg=4, traffic=traffic)
    elif strategy == "auto":
        descs, placement = snn.auto_segmentation_for(
            job.layers, n_segments=4, slots_per_seg=4)
    else:
        descs = snn.segmentation_for(job.layers, str(strategy),
                                     n_segments=int(rng.integers(3, 5)))
        placement = None
    backend = str(rng.choice(["sequential", "vmap", "threads"]))
    quantum = int(rng.choice([16, 32, 64]))
    cfg, ctl, meta = _run_vp(job, descs, placement, backend=backend,
                             quantum=quantum)
    got = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(
        got, job.expected_counts,
        err_msg=f"sizes={sizes} strategy={strategy} backend={backend} q={quantum}")
    assert snn.total_spikes(ctl.result_states()) == job.expected_total


# ---------------------------------------------------------------------------
# traffic-aware placement


def test_traffic_partition_respects_budgets_and_cuts():
    rng = np.random.default_rng(7)
    widths = [1, 1, 2, 3, 1, 2]
    loads = rng.random(6) * 10
    traffic = rng.random((6, 6)) * np.array(rng.random((6, 6)) < 0.5)
    assign = sg.traffic_partition(widths, loads, traffic, n_segments=4,
                                  slots_per_seg=3)
    # capacity respected, every group placed
    assert assign.min() >= 0
    for s in range(4):
        assert sum(w for w, a in zip(widths, assign) if a == s) <= 3
    # deterministic
    again = sg.traffic_partition(widths, loads, traffic, n_segments=4,
                                 slots_per_seg=3)
    np.testing.assert_array_equal(assign, again)

    def cut(a):
        return float((traffic * (np.asarray(a)[:, None] != np.asarray(a)[None, :])).sum())

    # no better than the optimizer: chain-order first-fit packing
    naive, used, s = [], 0, 0
    for w in widths:
        if used + w > 3:
            s, used = s + 1, 0
        naive.append(s)
        used += w
    assert cut(assign) <= cut(naive) + 1e-9


def test_traffic_aware_auto_reduces_cut_and_stays_exact():
    job = FANIN_JOB
    rates, traffic = snn.profile_traffic(job.layers, job.raster)
    assert rates.shape == (len(snn.layer_groups(job.layers)),)
    assert (rates >= 0).all() and traffic.sum() > 0
    descs, placement = snn.auto_segmentation_for(
        job.layers, n_segments=4, slots_per_seg=4, traffic=traffic)
    cfg, ctl, meta = _run_vp(job, descs, placement)
    got = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(got, job.expected_counts)
    # the hot 600-neuron producer stripes and their consumer group end up
    # packed: cross-segment traffic is no worse than the chain-order default
    def seg_of(placement_, descs_):
        caps = np.cumsum([0] + [d.n_cims for d in descs_])
        return [int(np.searchsorted(caps, p, side="right") - 1)
                for p in placement_]

    from repro.snn import topology

    naive_descs = snn.segmentation_for(job.layers, "uniform", n_segments=4)
    naive_placement = topology._default_placement(
        snn.layer_groups(job.layers), naive_descs)

    def cut(assign):
        a = np.asarray(assign)
        return float((traffic * (a[:, None] != a[None, :])).sum())

    assert cut(seg_of(placement, descs)) <= cut(seg_of(naive_placement, naive_descs)) + 1e-9


def test_measured_traffic_matches_profile_structure():
    """Rates measured from a VP run agree with the oracle profiling pass up
    to the tick-count normalization (the VP terminates as soon as the net
    drains; the oracle always simulates the full T+L+1 window)."""
    descs = snn.segmentation_for(FANIN_JOB.layers, "uniform", n_segments=3)
    cfg, ctl, meta = _run_vp(FANIN_JOB, descs)
    m_rates, m_traffic = snn.measure_traffic(ctl.result_states(), meta)
    o_rates, o_traffic = snn.profile_traffic(FANIN_JOB.layers, FANIN_JOB.raster)
    assert (m_traffic > 0).sum() == (o_traffic > 0).sum()
    # emitted *totals* are exact (rates differ only by tick normalization)
    groups = snn.layer_groups(FANIN_JOB.layers)
    got_totals = []
    cims = ctl.result_states()["cims"]
    for info in meta["groups"]:
        s, k = info["units"][0]
        got_totals.append(int(np.asarray(cims["spike_counts"][s, k]).sum()))
    per_neuron, _ = snn.oracle_rates(FANIN_JOB.layers, FANIN_JOB.raster)
    want_totals = [int(per_neuron[g.layer][g.r0:g.r1].sum()) for g in groups]
    assert got_totals == want_totals
