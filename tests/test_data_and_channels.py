"""Data pipeline determinism + channel buffer invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import channel as ch
from repro.train.data import DataConfig, batch_at


def test_data_deterministic_and_step_dependent():
    dc = DataConfig(vocab_size=512, seq_len=64, global_batch=4, seed=1)
    a = np.asarray(batch_at(dc, 3)["tokens"])
    b = np.asarray(batch_at(dc, 3)["tokens"])
    c = np.asarray(batch_at(dc, 4)["tokens"])
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 512


def test_data_has_learnable_structure():
    dc = DataConfig(vocab_size=512, seq_len=128, global_batch=8, seed=0)
    t = np.asarray(batch_at(dc, 0)["tokens"])
    period = dc.structure
    same = (t[:, period:] == (t[:, :-period] + 1) % 64).mean()
    assert same > 0.5  # shifted-copy structure dominates the noise


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 3),
                          st.integers(0, 1000), st.integers(-99, 99)),
                min_size=0, max_size=40),
       st.integers(2, 4))
def test_route_preserves_messages_and_order(msgs, n_seg):
    """Every valid message lands exactly once at its destination, in source
    order, with t_avail = t_emit + latency[src, dst]."""
    cap = 64
    out = jax.vmap(lambda _: ch.empty_box(cap))(jnp.arange(n_seg))
    lat = jnp.asarray(np.full((n_seg, n_seg), 10), jnp.int32)
    per_src = {s: [] for s in range(n_seg)}
    for kind, dst, t, data in msgs:
        dst = dst % n_seg
        src = (dst + 1) % n_seg
        per_src[src].append((kind, dst, t, data))
    boxes = []
    for s in range(n_seg):
        box = ch.empty_box(cap)
        for kind, dst, t, data in per_src[s]:
            box = ch.box_append(box, jnp.asarray(True), kind, dst, 7, data, t)
        boxes.append(box)
    stacked = jax.tree.map(lambda *v: jnp.stack(v), *boxes)
    inboxes = ch.route(stacked, lat, cap)
    for d in range(n_seg):
        expected = []
        for s in range(n_seg):
            expected += [(k, dd, t + 10, dat) for (k, dd, t, dat) in per_src[s] if dd == d]
        got_n = int(inboxes["count"][d])
        assert got_n == len(expected)
        got = [
            (int(inboxes["kind"][d][i]), d, int(inboxes["t_avail"][d][i]), int(inboxes["data"][d][i]))
            for i in range(got_n)
        ]
        # per-source order must be preserved (stable routing)
        for s in range(n_seg):
            src_expected = [(k, d, t + 10, dat) for (k, dd, t, dat) in per_src[s] if dd == d]
            src_got = [g for g in got if g in src_expected]
            for e in src_expected:
                assert e in got


def test_merge_pending_appends_after_pack():
    pend = ch.empty_pending(16)
    # one applied (invalid) + one live message
    pend["valid"] = pend["valid"].at[3].set(True)
    pend["data"] = pend["data"].at[3].set(99)
    fresh = ch.empty_pending(16)
    fresh["valid"] = fresh["valid"].at[0].set(True)
    fresh["data"] = fresh["data"].at[0].set(42)
    merged = ch.merge_pending(pend, fresh)
    assert int(merged["count"]) == 2
    assert int(merged["data"][0]) == 99 and int(merged["data"][1]) == 42
