"""Observability subsystem: trace-ring mechanics, drain/loss accounting,
metrics registry + the ``stats()`` back-compat contract, and the
Chrome-trace/NDJSON exporters.

The cross-cutting guarantees (tracing bit-invisible on every backend ×
dispatch mode, one host sync per fused dispatch) live in
tests/test_conformance.py; this file covers the obs/ package itself.
"""
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import snn
from repro.core.controller import Controller
from repro.obs import TraceConfig, export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as tr


def _stack1(ring):
    """A single ring as the stacked (1, ...) layout ``drain`` expects."""
    return jax.tree.map(lambda x: np.asarray(x)[None], ring)


# ---------------------------------------------------------------------------
# ring mechanics


def test_emit_respects_mask_and_drain_sorts_by_time():
    ring = tr.ring_state(8)
    for i, t in enumerate((5, 3, 7)):
        ring = tr.emit(ring, True, tr.EV_TICK, 0, i, t, i * 10)
    ring = tr.emit(ring, False, tr.EV_TICK, 0, 99, 0, 0)  # masked out
    assert int(ring["count"]) == 3
    events, lost = tr.drain(_stack1(ring))
    assert lost == 0 and len(events) == 3
    assert events["t"].tolist() == [3, 5, 7]          # chronological
    assert events["unit"].tolist() == [1, 0, 2]       # records follow
    assert 99 not in events["unit"].tolist()


def test_overflow_drops_records_but_counts_demand():
    ring = tr.ring_state(2)
    for i in range(5):
        ring = tr.emit(ring, True, tr.EV_QUANTUM, 0, 0, i, i)
    assert int(ring["count"]) == 5, "count records true demand"
    assert bool(ring["overflowed"])
    events, lost = tr.drain(_stack1(ring))
    assert lost == 3
    assert events["t"].tolist() == [0, 1], "first records survive, no wrap"


def test_emit_bulk_matches_sequential_emits():
    mask = jnp.array([True, False, True, True, False, True])
    unit = jnp.arange(6)
    t = jnp.array([4, 0, 2, 9, 0, 2])
    value = jnp.arange(6) * 7
    bulk = tr.emit_bulk(tr.ring_state(8), mask, tr.EV_SPIKE_TX, 1,
                        unit, t, value)
    seq = tr.ring_state(8)
    for i in range(6):
        seq = tr.emit(seq, bool(mask[i]), tr.EV_SPIKE_TX, 1,
                      int(unit[i]), int(t[i]), int(value[i]))
    assert int(bulk["count"]) == int(seq["count"]) == 4
    for f in tr.FIELDS:
        np.testing.assert_array_equal(np.asarray(bulk[f])[:4],
                                      np.asarray(seq[f])[:4])


def test_emit_bulk_truncates_at_capacity():
    mask = jnp.ones(5, bool)
    ring = tr.emit_bulk(tr.ring_state(3), mask, tr.EV_TICK, 0,
                        jnp.arange(5), jnp.arange(5), jnp.zeros(5, jnp.int32))
    assert int(ring["count"]) == 5 and bool(ring["overflowed"])
    events, lost = tr.drain(_stack1(ring))
    assert lost == 2 and events["unit"].tolist() == [0, 1, 2]


def test_reset_rewinds_count_but_keeps_sticky_flags():
    ring = tr.ring_state(1)
    for i in range(3):
        ring = tr.emit(ring, True, tr.EV_WMARK, 0, -1, i, 1)
    ring["wmark_seen"] = jnp.asarray(0b0010, jnp.int32)
    ring = tr.reset(ring)
    assert int(ring["count"]) == 0
    assert bool(ring["overflowed"]), "overflow is cross-drain sticky"
    assert int(ring["wmark_seen"]) == 0b0010, "watermark dedup is sticky"


# ---------------------------------------------------------------------------
# exporters (synthetic events: one of each kind)


def _events(recs):
    e = np.empty(len(recs), tr.EVENT_DTYPE)
    for i, r in enumerate(recs):
        e[i] = r
    return e


SYNTHETIC = _events([
    (tr.EV_QUANTUM, 0, 120, 0, 32),
    (tr.EV_ROUTE, 1, 4, 32, 6),
    (tr.EV_TICK, 1, 0, 40, 3),
    (tr.EV_SPIKE_TX, 1, 0, 40, (0 << 16) | 3),
    (tr.EV_CIM_START, 0, 1, 50, 90),
    (tr.EV_CIM_DONE, 0, 1, 90, 8),
    (tr.EV_WMARK, 0, -1, 95, 1),
    (tr.EV_FAULT, 1, 2, 96, 5),
    (tr.EV_SPIKE_LOSS, 0, -1, 97, 7),
])


def test_chrome_trace_schema_valid_and_json_roundtrips(tmp_path):
    obj = export.write_chrome_trace(tmp_path / "t.json", SYNTHETIC,
                                    tick_period=16)
    assert export.validate_chrome_trace(obj) == []
    back = json.loads((tmp_path / "t.json").read_text())
    assert back["traceEvents"] == obj["traceEvents"]
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert phases == {"M", "X", "C", "i", "s", "f"}
    # the spike flow lands at the destination segment one tick later
    s = next(e for e in obj["traceEvents"] if e["ph"] == "s")
    f = next(e for e in obj["traceEvents"] if e["ph"] == "f")
    assert f["pid"] == 0 and f["ts"] == s["ts"] + 16


def test_validate_rejects_malformed_traces():
    assert export.validate_chrome_trace({}) != []
    assert export.validate_chrome_trace({"traceEvents": []}) != []
    obj = export.to_chrome_trace(SYNTHETIC)
    bad = json.loads(json.dumps(obj))
    del next(e for e in bad["traceEvents"] if e["ph"] == "X")["ts"]
    assert any("ts" in p for p in export.validate_chrome_trace(bad))
    orphan = json.loads(json.dumps(obj))
    orphan["traceEvents"] = [e for e in orphan["traceEvents"]
                             if e["ph"] != "f"]
    assert any("s/f pair" in p for p in export.validate_chrome_trace(orphan))


def test_ndjson_writes_one_named_record_per_event():
    fh = io.StringIO()
    n = export.write_ndjson(fh, SYNTHETIC)
    lines = [json.loads(l) for l in fh.getvalue().splitlines()]
    assert n == len(lines) == len(SYNTHETIC)
    assert [l["kind"] for l in lines] == list(tr.KIND_NAMES)
    assert lines[1] == {"kind": "route", "seg": 1, "unit": 4, "t": 32,
                        "value": 6}


# ---------------------------------------------------------------------------
# a real traced run (shared fixture: hybrid = CPUs + dense CIM + SNN, so
# every metric source is exercised)


@pytest.fixture(scope="module")
def hybrid_run():
    job = snn.hybrid_job((16, 12, 8), t_steps=6, rate=0.5, seed=2)
    cfg, states, pending, meta = snn.build_hybrid(job, "packed",
                                                  channel_latency=2000)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=400,
                     obs=TraceConfig())
    ctl.run(max_rounds=800, check_every=2, fused=True)
    plain = Controller(cfg, states, pending, backend="vmap", quantum=400)
    plain.run(max_rounds=800, check_every=2, fused=True)
    return ctl, plain, job, meta, cfg


def test_stats_backcompat_contract(hybrid_run):
    """The historical stats() dict shape and values, pinned — the shim over
    obs/metrics.py must stay bit-compatible with pre-obs callers."""
    ctl = hybrid_run[0]
    st = ctl.stats()
    assert set(st) == {"instructions", "messages", "txn_histogram", "cache",
                       "dram", "cim_ops", "snn"}
    assert set(st["cache"]) == {"d_hits", "d_misses"}
    assert set(st["dram"]) == {"reads", "writes"}
    assert set(st["snn"]) == {"spikes", "ticks"}
    m = ctl.metrics()
    np.testing.assert_array_equal(st["instructions"], m["cpu.instructions"])
    np.testing.assert_array_equal(st["messages"],
                                  m["channel.messages_emitted"])
    np.testing.assert_array_equal(st["txn_histogram"],
                                  m["channel.txn_histogram"].sum(0))
    np.testing.assert_array_equal(st["cim_ops"], m["cim.dense_ops"])
    np.testing.assert_array_equal(st["snn"]["spikes"],
                                  m["snn.spikes_emitted"])
    assert int(st["instructions"].sum()) > 0
    assert int(st["cim_ops"].sum()) > 0
    assert int(st["snn"]["spikes"].sum()) > 0


def test_metrics_registry_is_typed_and_complete(hybrid_run):
    ctl = hybrid_run[0]
    for m in obs_metrics.REGISTRY.values():
        assert m.kind in ("counter", "gauge", "histogram"), m.name
        assert m.per in ("segment", "unit", "bin"), m.name
        assert m.source in ("states", "pending"), m.name
        assert m.description
    snap = ctl.metrics()
    assert set(snap) == set(obs_metrics.REGISTRY)
    # without a pending box, pending-sourced metrics are skipped, not wrong
    partial = obs_metrics.collect(ctl.result_states())
    assert set(partial) == {n for n, m in obs_metrics.REGISTRY.items()
                            if m.source == "states"}
    # the new consumed-side counters move (ROADMAP item 2 feed)
    assert int(snap["snn.spikes_consumed"].sum()) > 0
    assert int(snap["snn.spikes_in"].sum()) > 0
    assert int(snap["channel.messages_routed"].sum()) > 0


def test_trace_events_consistent_with_simulation(hybrid_run):
    ctl, plain, job, meta, cfg = hybrid_run
    ev = ctl.trace_events()
    assert ctl.trace_lost == 0
    kinds = ev["kind"]
    # every LIF spike shows up on a tick event exactly once
    fired = ev["value"][kinds == tr.EV_TICK].sum()
    assert int(fired) == int(snn.total_spikes(plain.result_states()))
    # quantum events only ever advance time
    assert (ev["value"][kinds == tr.EV_QUANTUM] > 0).all()
    # the exported timeline is schema-valid
    obj = export.to_chrome_trace(ev, tick_period=cfg.snn_tick_period)
    assert export.validate_chrome_trace(obj) == []


def test_undersized_ring_is_informational_never_perturbs():
    job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.5, seed=3)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
    ref = Controller(cfg, states, pending, backend="vmap", quantum=32)
    ref.run(max_rounds=300, check_every=2, fused=True)
    tiny = Controller(cfg, states, pending, backend="vmap", quantum=32,
                      obs=TraceConfig(capacity=8))
    tiny.run(max_rounds=300, check_every=2, fused=True)  # must not raise
    assert tiny.trace_lost > 0, "an 8-slot ring must overflow here"
    assert tiny.rounds_run == ref.rounds_run
    st = dict(tiny.result_states())
    st.pop("trace")
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(ref.result_states())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        snn.output_spike_counts(tiny.result_states(), meta),
        job.expected_counts)


def test_event_stream_identical_across_dispatch_modes():
    job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.5, seed=3)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    cfg, states, pending, _ = snn.build_snn(job.layers, descs, job.raster)
    streams = {}
    batches = {}
    for fused in (False, True):
        got = []
        ctl = Controller(cfg, states, pending, backend="vmap", quantum=32,
                         obs=TraceConfig())
        ctl.run(max_rounds=300, check_every=2, fused=fused,
                on_telemetry=got.append)
        streams[fused] = np.sort(ctl.trace_events(), order=list(tr.FIELDS))
        batches[fused] = got
    np.testing.assert_array_equal(streams[False], streams[True])
    # the callback saw exactly what trace_events() accumulated
    for fused, got in batches.items():
        assert sum(len(b) for b in got) == len(streams[fused])
        assert all(len(b) for b in got), "empty batches are not delivered"
