"""Cross-backend conformance: the canonical bit-exactness gate.

One parametrized sweep asserts that a dense CIM offload job, a
feed-forward SNN job, a recurrent SNN job, and a hybrid job (dense VMM +
spiking layers + two live RISC-V CPUs, the SNN raster injected over MMIO)
produce *bit-identical* final states, pending boxes, and round counts
across every controller backend (sequential / threads / vmap, per-round
and megaloop dispatch; shard_map rides in a multi-device subprocess) for
each segmentation strategy and quantum — and that every cell of the sweep
reproduces the workload's oracle expectation exactly.  The older per-feature equivalence
checks (tests/test_snn.py, tests/test_snn_wide.py, tests/test_megaloop.py)
stay as focused diagnostics; this sweep is the gate new execution paths
must pass.

A seeded hypothesis property sweep rides on top when the 'test' extra is
installed (CI runs it with a fixed --hypothesis-seed).

Also here: the controller-lifecycle, CPU-free fast-path, and channel-cap
watermark hardening tests — conformance of resource handling and error
behavior across execution paths.
"""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro import snn
from repro.core import channel as ch
from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# job builders: (cfg, states, pending) + an oracle check per workload class


DENSE_LAYER = wl.Layer("conf", "t", 8, 8, 4)
FF_JOB = snn.snn_inference_job((32, 24, 10), t_steps=8, rate=0.5, seed=2)
REC_JOB = snn.snn_recurrent_job((32, 24, 8), t_steps=8, rate=0.5, seed=2)
SKIP_JOB = snn.snn_skip_job((32, 24, 16, 10), t_steps=8, rate=0.5, seed=2)
HYBRID_JOB = snn.hybrid_job((16, 12, 8), t_steps=6, rate=0.5, seed=2)


def build_dense(strategy):
    if strategy == "uniform":
        descs = sg.uniform(2, 2)
        mgrs, ids = [0, 1], {0: (0, 1), 1: (2, 3)}
    else:
        descs = sg.load_oriented()
        mgrs, ids = [1], {1: (0, 2)}
    job = wl.cim_workload(DENSE_LAYER, mgr_segments=mgrs, cim_ids_per_mgr=ids,
                          ordinals=sg.mailbox_ordinals(descs))
    cfg, states, pending = sg.build(
        descs, programs=job["programs"], dram_words=job["dram"],
        crossbars=job["crossbars"], scratch_init=job["scratch"],
        channel_latency=2000)

    def check(ctl):
        st = ctl.result_states()
        o = np.asarray(st["dram"]["data"][0][
            job["o_word"]: job["o_word"] + DENSE_LAYER.h * DENSE_LAYER.p
        ]).reshape(DENSE_LAYER.h, DENSE_LAYER.p)
        np.testing.assert_array_equal(o, job["expected"])

    return (cfg, states, pending), check


def build_snn_job(job, strategy):
    descs = snn.segmentation_for(job.layers, strategy, n_segments=4,
                                 edges=job.edges)
    cfg, states, pending, meta = snn.build_snn(
        job.layers, descs, job.raster, edges=job.edges, n_ticks=job.n_ticks)

    def check(ctl):
        st = ctl.result_states()
        np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                      job.expected_counts)
        assert snn.total_spikes(st) == job.expected_total

    return (cfg, states, pending), check


def build_hybrid_job(strategy):
    """Live CPUs + dense units + spike units in one platform: CPU1 injects
    the raster via CIM_REG_SPIKE, reads counts back via CIM_REG_COUNTS and
    publishes them to shared DRAM while CPU0 runs the dense offload."""
    job = HYBRID_JOB
    # the dense/SNN strategy names map onto the hybrid platform shapes
    hs = {"uniform": "packed", "load_oriented": "split"}.get(strategy, strategy)
    cfg, states, pending, meta = snn.build_hybrid(job, hs,
                                                  channel_latency=2000)

    def check(ctl):
        st = ctl.result_states()
        o, counts = snn.hybrid_results(st, meta)
        np.testing.assert_array_equal(o, job.dense_expected)
        np.testing.assert_array_equal(counts, job.snn.expected_counts)
        np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                      job.snn.expected_counts)
        assert snn.total_spikes(st) == job.snn.expected_total

    return (cfg, states, pending), check


def build_sim(kind, strategy):
    if kind == "dense":
        return build_dense(strategy)
    if kind == "snn_ff":
        return build_snn_job(FF_JOB, strategy)
    if kind == "snn_recurrent":
        return build_snn_job(REC_JOB, strategy)
    if kind == "snn_skip":
        return build_snn_job(SKIP_JOB, strategy)
    if kind == "hybrid":
        return build_hybrid_job(strategy)
    raise ValueError(kind)


MODES = (  # every in-process execution path
    ("sequential", "sequential", None),
    ("threads", "threads", None),
    ("vmap/per-round", "vmap", False),
    ("vmap/megaloop", "vmap", True),
)


def run_mode(sim, backend, quantum, fused, check_every=2, max_rounds=400,
             obs=None):
    cfg, states, pending = sim
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum,
                     obs=obs)
    rounds, _ = ctl.run(max_rounds=max_rounds, check_every=check_every,
                        fused=fused)
    states_out = dict(ctl.result_states())
    states_out.pop("trace", None)  # the ring is telemetry, not simulation
    out = (rounds, states_out, ctl._pending_stacked())
    return out, ctl


def assert_identical(got, ref, label):
    assert got[0] == ref[0], f"{label}: round counts {got[0]} vs {ref[0]}"
    for x, y in zip(jax.tree.leaves(got[1]), jax.tree.leaves(ref[1])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{label}: states differ")
    for x, y in zip(jax.tree.leaves(got[2]), jax.tree.leaves(ref[2])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{label}: pending differs")


# ---------------------------------------------------------------------------
# the canonical sweep


SWEEP = [
    ("dense", "uniform", 1000), ("dense", "uniform", 2000),
    ("dense", "load_oriented", 1000),
    ("snn_ff", "uniform", 16), ("snn_ff", "uniform", 64),
    ("snn_ff", "load_oriented", 32),
    ("snn_recurrent", "uniform", 16), ("snn_recurrent", "uniform", 64),
    ("snn_recurrent", "load_oriented", 32),
    # forward skip connection (layer 0 -> output, dst > src + 1): acyclic,
    # drains without a horizon, oracle-exact on every backend
    ("snn_skip", "uniform", 32), ("snn_skip", "load_oriented", 32),
    # hybrid: dense VMM + SNN + two live CPUs in one platform, raster
    # CPU-injected — ≥2 segmentations x ≥2 quanta (the PR-5 gate)
    ("hybrid", "split", 400), ("hybrid", "split", 1000),
    ("hybrid", "packed", 400), ("hybrid", "packed", 1000),
    ("hybrid", "auto", 700),
]


@pytest.mark.parametrize("kind,strategy,quantum", SWEEP)
def test_conformance_sweep(kind, strategy, quantum):
    sim, check = build_sim(kind, strategy)
    ref = None
    for label, backend, fused in MODES:
        got, ctl = run_mode(sim, backend, quantum, fused)
        check(ctl)  # every cell reproduces the oracle expectation exactly
        ctl.close()
        if ref is None:
            ref = got
        else:
            assert_identical(got, ref, f"{kind}/{strategy}/q{quantum}/{label}")


def test_conformance_shard_map(subproc):
    """The fourth backend: shard_map (one device per segment) must match
    vmap bit-for-bit on all three workload classes."""
    subproc(
        """
import jax, numpy as np
from repro import compat, snn
from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

mesh = compat.make_mesh((2,), ("segment",))

def both(cfg, states, pending, quantum):
    res = {}
    for backend, kw in (("vmap", {}), ("shard_map", {"mesh": mesh})):
        ctl = Controller(cfg, states, pending, backend=backend,
                         quantum=quantum, **kw)
        rounds, _ = ctl.run(max_rounds=400, check_every=2)
        res[backend] = (rounds, ctl.result_states(), ctl._pending_stacked())
    assert res["vmap"][0] == res["shard_map"][0]
    for x, y in zip(jax.tree.leaves(res["vmap"][1:]),
                    jax.tree.leaves(res["shard_map"][1:])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

# dense
layer = wl.Layer("conf", "t", 8, 8, 4)
descs = sg.uniform(2, 2)
job = wl.cim_workload(layer, mgr_segments=[0, 1],
                      cim_ids_per_mgr={0: (0, 1), 1: (2, 3)},
                      ordinals=sg.mailbox_ordinals(descs))
cfg, states, pending = sg.build(descs, programs=job["programs"],
                                dram_words=job["dram"],
                                crossbars=job["crossbars"],
                                scratch_init=job["scratch"],
                                channel_latency=2000)
both(cfg, states, pending, 1000)

# feed-forward SNN
ff = snn.snn_inference_job((24, 16, 8), t_steps=6, rate=0.5, seed=2)
descs = snn.segmentation_for(ff.layers, "uniform", n_segments=2)
cfg, states, pending, _ = snn.build_snn(ff.layers, descs, ff.raster)
both(cfg, states, pending, 32)

# recurrent SNN
rec = snn.snn_recurrent_job((24, 16, 8), t_steps=6, rate=0.5, seed=2)
descs = snn.segmentation_for(rec.layers, "uniform", n_segments=2,
                             edges=rec.edges)
cfg, states, pending, _ = snn.build_snn(rec.layers, descs, rec.raster,
                                        edges=rec.edges, n_ticks=rec.n_ticks)
both(cfg, states, pending, 32)

# hybrid: dense + SNN + two live CPUs (packed = 2 segments = 2 devices)
hy = snn.hybrid_job((16, 12, 8), t_steps=6, rate=0.5, seed=2)
cfg, states, pending, _ = snn.build_hybrid(hy, "packed",
                                           channel_latency=2000)
both(cfg, states, pending, 400)
print("shard_map conformance OK")
""",
        n_devices=2,
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        kind=st.sampled_from(["dense", "snn_ff", "snn_recurrent", "hybrid"]),
        strategy=st.sampled_from(["uniform", "load_oriented"]),
        backend_fused=st.sampled_from(
            [("threads", None), ("vmap", False), ("vmap", True)]),
        q_index=st.integers(min_value=0, max_value=2),
        check_every=st.integers(min_value=1, max_value=4),
    )
    def test_conformance_property(kind, strategy, backend_fused, q_index,
                                  check_every):
        """Random (job, segmentation, backend, quantum, check cadence):
        always bit-identical to the sequential reference at the same
        cadence, and always oracle-exact."""
        quantum = {"dense": (500, 1000, 2000),
                   "hybrid": (400, 700, 1000)}.get(kind, (16, 32, 64))[q_index]
        sim, check = build_sim(kind, strategy)
        ref, ctl = run_mode(sim, "sequential", quantum, None,
                            check_every=check_every)
        check(ctl)
        backend, fused = backend_fused
        got, ctl = run_mode(sim, backend, quantum, fused,
                            check_every=check_every)
        check(ctl)
        ctl.close()
        assert_identical(got, ref, f"{kind}/{strategy}/q{quantum}/{backend}")


# ---------------------------------------------------------------------------
# telemetry conformance: tracing must be invisible to the simulation


OBS_SWEEP = [  # one representative cell per workload class
    ("dense", "uniform", 1000),
    ("snn_ff", "uniform", 32),
    ("snn_recurrent", "uniform", 32),
    ("hybrid", "packed", 400),
]


@pytest.mark.parametrize("kind,strategy,quantum", OBS_SWEEP)
def test_tracing_is_bit_invisible(kind, strategy, quantum):
    """obs=TraceConfig() must not change results, rounds_run, sim_time, or
    pending boxes on any in-process backend × dispatch mode — and the
    traced run still reproduces its oracle exactly."""
    from repro.obs import TraceConfig

    sim, check = build_sim(kind, strategy)
    for label, backend, fused in MODES:
        plain, pctl = run_mode(sim, backend, quantum, fused)
        pctl.close()
        traced, tctl = run_mode(sim, backend, quantum, fused,
                                obs=TraceConfig())
        check(tctl)
        assert len(tctl.trace_events()), f"{label}: traced run saw no events"
        tctl.close()
        assert_identical(traced, plain,
                         f"{kind}/{strategy}/q{quantum}/{label}/traced")


def test_one_host_sync_per_fused_dispatch_with_telemetry(monkeypatch):
    """The megaloop contract with telemetry ON: each fused dispatch performs
    exactly one host fetch (the (rounds, done, over, ring) tuple) — draining
    the trace rings must not add device syncs."""
    import repro.core.controller as ctl_mod
    from repro.obs import TraceConfig

    real, calls = ctl_mod._HOST_FETCH, []

    def counting_fetch(tree):
        calls.append(1)
        return real(tree)

    monkeypatch.setattr(ctl_mod, "_HOST_FETCH", counting_fetch)
    sim, check = build_sim("snn_ff", "uniform")
    ctl = Controller(*sim, backend="vmap", quantum=32, obs=TraceConfig())
    ctl.run(max_rounds=400, check_every=2, fused=True,
            rounds_per_dispatch=64)
    check(ctl)
    assert ctl.dispatches >= 1
    assert len(calls) == ctl.dispatches == ctl.dispatch_syncs, \
        "fused dispatches must stay one-host-sync each with tracing on"


def test_stats_shim_matches_across_backends():
    """stats() (the back-compat shim over obs/metrics.py) returns the same
    dict on every backend — the coarse counters are part of the conformance
    surface, not just the raw states."""
    sim, _ = build_sim("snn_ff", "uniform")
    ref = None
    for label, backend, fused in MODES:
        _, ctl = run_mode(sim, backend, 32, fused)
        st = ctl.stats()
        ctl.close()
        assert set(st) == {"instructions", "messages", "txn_histogram",
                           "cache", "dram", "cim_ops", "snn"}
        if ref is None:
            ref = st
        else:
            for x, y in zip(jax.tree.leaves(st), jax.tree.leaves(ref)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"{label}: stats() differs")


def test_conformance_shard_map_traced(subproc):
    """Telemetry on the fourth backend: a traced shard_map run must match a
    traced vmap run bit-for-bit (states minus the ring AND the drained
    event stream), and both must match the untraced reference."""
    subproc(
        """
import jax, numpy as np
from repro import compat, snn
from repro.core.controller import Controller
from repro.obs import TraceConfig
from repro.obs import trace as tr

mesh = compat.make_mesh((2,), ("segment",))
ff = snn.snn_inference_job((24, 16, 8), t_steps=6, rate=0.5, seed=2)
descs = snn.segmentation_for(ff.layers, "uniform", n_segments=2)
cfg, states, pending, _ = snn.build_snn(ff.layers, descs, ff.raster)

res = {}
for name, backend, kw, obs in (
        ("vmap", "vmap", {}, None),
        ("vmap+obs", "vmap", {}, TraceConfig()),
        ("shard+obs", "shard_map", {"mesh": mesh}, TraceConfig())):
    ctl = Controller(cfg, states, pending, backend=backend, quantum=32,
                     obs=obs, **kw)
    rounds, _ = ctl.run(max_rounds=400, check_every=2)
    st = dict(ctl.result_states()); st.pop("trace", None)
    ev = np.sort(ctl.trace_events(), order=list(tr.FIELDS))
    res[name] = (rounds, st, ctl._pending_stacked(), ev)

for name in ("vmap+obs", "shard+obs"):
    assert res[name][0] == res["vmap"][0], name
    for x, y in zip(jax.tree.leaves(res[name][1:3]),
                    jax.tree.leaves(res["vmap"][1:3])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)
np.testing.assert_array_equal(res["shard+obs"][3], res["vmap+obs"][3])
assert len(res["shard+obs"][3]) > 0
print("traced shard_map conformance OK")
""",
        n_devices=2,
    )


# ---------------------------------------------------------------------------
# threads backend lifecycle


def test_threads_lifecycle_close_is_idempotent_and_leakless():
    sim, check = build_sim("snn_ff", "uniform")
    before = {t for t in threading.enumerate()}
    ctl = Controller(*sim, backend="threads", quantum=32)
    ctl.run(max_rounds=300, check_every=2)
    check(ctl)
    assert any(t.name.startswith("vp-seg") for t in threading.enumerate()), \
        "the persistent pool must exist while the controller is open"
    ctl.close()
    ctl.close()  # idempotent
    leaked = [t for t in threading.enumerate()
              if t not in before and t.name.startswith("vp-seg")]
    assert not leaked, f"threads backend leaked workers: {leaked}"
    # results stay readable after close; running again does not
    check(ctl)
    with pytest.raises(RuntimeError, match="closed"):
        ctl.run(max_rounds=1)
    with pytest.raises(RuntimeError, match="closed"):
        ctl.round()


def test_close_applies_to_every_backend():
    sim, _ = build_sim("snn_ff", "uniform")
    ctl = Controller(*sim, backend="vmap", quantum=32)
    ctl.close()
    with pytest.raises(RuntimeError, match="closed"):
        ctl.run(max_rounds=1)


# ---------------------------------------------------------------------------
# CPU-free fast path: hand-injected MMIO must fall back, bit-for-bit


def test_cpu_free_fast_path_and_mmio_fallback():
    job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.6, seed=5)
    descs = snn.segmentation_for(2, "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
    assert not cfg.has_cpu, "an SNN-only build takes the CPU-free fast path"
    # clean build: the fast path is kept
    clean = Controller(cfg, states, pending, backend="vmap", quantum=32)
    assert not clean.cfg.has_cpu
    clean.run(max_rounds=300, check_every=2)
    np.testing.assert_array_equal(
        snn.output_spike_counts(clean.result_states(), meta),
        job.expected_counts)

    # hand-inject an MMIO message (scratch DMA) into the pending box: the
    # fast path would silently ignore it, so the controller must detect it
    # and fall back to the full step
    injected = dict(pending)
    for f, v in (("kind", ch.MSG_W_SCRATCH), ("addr", 7), ("data", 1234),
                 ("t_avail", 0)):
        injected[f] = injected[f].at[0, -1].set(v)
    injected["valid"] = injected["valid"].at[0, -1].set(True)

    fall = Controller(cfg, states, injected, backend="vmap", quantum=32)
    assert fall.cfg.has_cpu, "hand-injected MMIO must force the full path"
    fall.run(max_rounds=300, check_every=2)

    # explicit full-path build with the same injection: bit-for-bit equal
    full_cfg = dataclasses.replace(cfg, has_cpu=True)
    full = Controller(full_cfg, states, injected, backend="vmap", quantum=32)
    full.run(max_rounds=300, check_every=2)
    assert fall.rounds_run == full.rounds_run
    for a, b in zip(jax.tree.leaves(fall.result_states()),
                    jax.tree.leaves(full.result_states())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the injected scratch word actually landed (the full path ran) and the
    # spike results still match the oracle
    st = fall.result_states()
    assert int(np.asarray(st["scratch"][0, 7])) == 1234
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                  job.expected_counts)


# ---------------------------------------------------------------------------
# undersized channel caps raise the watermark RuntimeError, loudly


BURST_SIZES = (8, 200, 8)  # 200-neuron middle layer -> 200-spike AER bursts


def _burst_sim(**caps):
    job = snn.snn_inference_job(BURST_SIZES, t_steps=3, rate=0.9, seed=4)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    return snn.build_snn(job.layers, descs, job.raster, **caps)[:3]


@pytest.mark.parametrize("fused", [False, True])
def test_undersized_out_cap_raises_actionable_error(fused):
    cfg, states, pending = _burst_sim(out_cap=64)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    with pytest.raises(RuntimeError, match=r"outbox overflow.*out_cap") as ei:
        ctl.run(max_rounds=300, check_every=2, fused=fused)
    assert "raise out_cap" in str(ei.value)
    # remediation hint: the watermark records demand, so the message names
    # the smallest out_cap that would have absorbed the burst
    assert "smallest sufficient out_cap=" in str(ei.value)
    peak = int(np.asarray(ctl.result_states()["stats"]["outbox_peak"]).max())
    assert f"smallest sufficient out_cap={peak}" in str(ei.value)


@pytest.mark.parametrize("fused", [False, True])
def test_undersized_in_cap_raises_actionable_error(fused):
    # in_cap holds the tiny raster (builder check) but not the 200-spike
    # runtime burst landing in the consumer segment's inbox
    cfg, states, pending = _burst_sim(in_cap=80)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    with pytest.raises(RuntimeError, match=r"inbox overflow.*in_cap") as ei:
        ctl.run(max_rounds=300, check_every=2, fused=fused)
    assert "raise in_cap" in str(ei.value)
    peak = int(np.asarray(ctl._pending_stacked()["max_count"]).max())
    assert f"smallest sufficient in_cap={peak}" in str(ei.value)


@pytest.mark.parametrize("fused", [False, True])
def test_undersized_store_log_raises_actionable_error(fused):
    # a RISC-V VMM writing its whole output matrix in one quantum needs
    # h*p store-log entries; store_log=2 must trip the sticky watermark
    layer = wl.Layer("conf", "t", 8, 8, 4)
    job = wl.riscv_workload(layer)
    descs = [sg.SegmentDesc(cpu=True, dram=True)]
    cfg, states, pending = sg.build(descs, programs=job["programs"],
                                    dram_words=job["dram"], store_log=2)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=20_000)
    with pytest.raises(RuntimeError, match=r"store-log overflow.*store_log") as ei:
        ctl.run(max_rounds=100, check_every=2, fused=fused)
    assert "raise store_log" in str(ei.value)
    assert "smallest sufficient store_log=" in str(ei.value)


def test_error_messages_identical_fused_and_per_round():
    msgs = {}
    for fused in (False, True):
        cfg, states, pending = _burst_sim(out_cap=64)
        ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
        with pytest.raises(RuntimeError) as ei:
            ctl.run(max_rounds=300, check_every=2, fused=fused)
        msgs[fused] = str(ei.value)
    assert msgs[False] == msgs[True]
