"""Coverage for the remaining substrate: cell bookkeeping, async checkpoint,
assembler round trips, workload generators, mesh helpers."""
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, all_cells, get_config, skipped_cells


def test_cell_grid_is_complete():
    """10 archs × 4 shapes = 40 cells: 32 runnable + 8 documented skips."""
    runnable = all_cells()
    skips = skipped_cells()
    assert len(ARCH_IDS) == 10 and len(SHAPES) == 4
    assert len(runnable) + len(skips) == 40
    assert len(runnable) == 32
    skipped = {(a, s) for a, s, _ in skips}
    assert all(s == "long_500k" for _, s, _ in skips)
    assert ("falcon-mamba-7b", "long_500k") in runnable
    assert ("zamba2-2.7b", "long_500k") in runnable
    assert skipped.isdisjoint(set(runnable))


def test_exact_assigned_configs():
    """Spot-check the assignment table made it into the configs verbatim."""
    g = get_config("granite-34b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff, g.vocab_size) == (
        88, 6144, 48, 1, 24576, 49152)
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_layers, k.d_model, k.moe.n_experts, k.moe.top_k, k.vocab_size) == (
        61, 7168, 384, 8, 163840)
    z = get_config("zamba2-2.7b")
    assert (z.n_layers, z.ssm.d_state, z.attn_every) == (54, 64, 6)
    f = get_config("falcon-mamba-7b")
    assert (f.n_layers, f.d_model, f.ssm.d_state, f.vocab_size) == (64, 4096, 16, 65024)


def test_async_checkpoint(tmp_path):
    import jax

    from repro.train import checkpoint as ckpt

    tree = {"a": jnp.arange(100.0), "b": {"c": jnp.ones((3, 4))}}
    t = ckpt.save(tmp_path, 7, tree, async_write=True)
    t.join(timeout=60)
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_assembler_negative_branches_and_loops():
    from repro.vp.assembler import assemble

    words = assemble(
        """
    top:
        addi t0, t0, 1
        blt t0, t1, top
        halt
        """
    )
    assert len(words) == 3
    # backward branch immediate must be negative (bit 31 set)
    assert words[1] >> 31 == 1


def test_workload_generators_shapes():
    from repro.vp import workloads as wl

    layer = wl.Layer("x", "y", 10, 8, 3)
    a, b, o = wl.layer_data(layer)
    assert a.shape == (10, 8) and b.shape == (8, 3) and o.shape == (10, 3)
    np.testing.assert_array_equal(o, a @ b)
    job = wl.cim_workload(layer, [0], {0: (0, 1)})
    assert 0 in job["programs"] and 0 in job["crossbars"]
    tiles = wl.from_arch("qwen3-1.7b", max_tiles=3)
    assert tiles and all(t.h == 256 and t.w == 256 for t in tiles)


def test_padded_heads_policy():
    from repro.configs import get_config
    from repro.models.layers import padded_heads

    assert padded_heads(get_config("llama4-scout-17b-a16e"), 16) == 48  # 40 -> pad
    assert padded_heads(get_config("qwen3-1.7b"), 16) == 16  # divisible
    assert padded_heads(get_config("whisper-tiny"), 16) == 6  # 16/6 > 1.5x: replicate
    assert padded_heads(get_config("granite-34b"), 16) == 48  # divisible


def test_mesh_helpers_shapes():
    # make_production_mesh needs 256/512 devices — only check the spec here
    import inspect

    from repro.launch import mesh as M

    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src
