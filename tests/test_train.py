"""Training substrate: optimizer semantics, checkpoint fault tolerance,
gradient compression, time-decoupled pod DP."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.common import init_params, shape_dtypes
from repro.configs import get_smoke_config
from repro.models.model import build
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train.data import DataConfig, batch_at
from repro.train.optimizer import OptConfig, adamw_update, opt_specs, zero1_pspec
from repro.train.train_step import make_train_step, state_specs
from repro.common import ParamSpec
from jax.sharding import PartitionSpec as P


def small_setup(arch="qwen3-1.7b", accum=1):
    cfg = get_smoke_config(arch)
    model = build(cfg, tp=1)
    oc = OptConfig(lr=3e-3, warmup_steps=5, total_steps=100, moments_dtype=cfg.moments_dtype)
    sspecs = state_specs(model, oc)
    state = {
        "params": model.init(jax.random.PRNGKey(0)),
        "opt": init_params(jax.random.PRNGKey(1), sspecs["opt"]),
    }
    step = jax.jit(make_train_step(model, oc, accum_steps=accum))
    dc = DataConfig(cfg.vocab_size, 64, 8, seed=3)
    return model, state, step, dc


def test_loss_decreases():
    model, state, step, dc = small_setup()
    losses = []
    for i in range(40):
        state, m = step(state, batch_at(dc, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_grad_accumulation_matches_single_batch():
    model, state, step1, dc = small_setup(accum=1)
    _, _, step4, _ = small_setup(accum=4)
    b = batch_at(dc, 0)
    s1, m1 = step1(state, b)
    s4, m4 = step4(jax.tree.map(jnp.copy, state), b)
    # same data, same total batch: losses match, params close
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    d = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b2.astype(jnp.float32))))
        for a, b2 in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"]))
    )
    assert d < 5e-3, d  # one AdamW step over bf16 microbatch-split forwards


def test_int8_moments_update_close_to_fp32():
    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (64, 128))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 128)) * 0.1}
    for dtype in (jnp.float32, jnp.int8):
        oc = OptConfig(lr=1e-2, moments_dtype=dtype)
        specs = {"w": ParamSpec((64, 128), jnp.float32, P())}
        opt = init_params(key, opt_specs(specs, oc))
        newp, _, _ = adamw_update(oc, p, g, opt)
        if dtype == jnp.float32:
            ref = newp["w"]
        else:
            np.testing.assert_allclose(np.asarray(newp["w"]), np.asarray(ref), atol=2e-3)


def test_zero1_pspec_no_duplicates():
    s = ParamSpec((60, 384, 7168, 2048), jnp.bfloat16, P(None, "model", None, "data"))
    assert zero1_pspec(s) == P(None, "model", None, "data")  # untouched (data used)
    s2 = ParamSpec((1024, 512), jnp.float32, P(None, "model"))
    assert zero1_pspec(s2) == P("data", "model")


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    model, state, step, dc = small_setup()
    state, _ = step(state, batch_at(dc, 0))
    ckpt.save(tmp_path, 1, state)
    state, _ = step(state, batch_at(dc, 1))
    ckpt.save(tmp_path, 2, state)
    assert ckpt.latest_step(tmp_path) == 2
    restored, at = ckpt.restore(tmp_path, state)
    assert at == 2
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corrupt the newest -> restore falls back to the previous valid one
    ckpt.corrupt_for_test(tmp_path, 2)
    assert ckpt.latest_step(tmp_path) == 1
    _, at = ckpt.restore(tmp_path, state)
    assert at == 1


def test_train_driver_failure_resume(tmp_path):
    """End-to-end fault tolerance: crash at step 30, resume, finish."""
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH="src")
    args = [sys.executable, "-m", "repro.launch.train", "--steps", "40", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
            "--log-every", "100"]
    r1 = subprocess.run(args + ["--fail-at-step", "30"], env=env, capture_output=True,
                        text=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r1.returncode == 17, r1.stderr[-1500:]
    r2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r2.returncode == 0, r2.stderr[-1500:]
    assert "resumed from checkpoint step 30" in r2.stdout
    assert "training complete" in r2.stdout


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([(17,), (256,), (64, 129)]))
def test_compression_roundtrip_error_bound(seed, shape):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
    q, s, shp = comp.compress(x)
    back = comp.decompress(q, s, shp)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(back - x))) <= blockmax / 127.0 + 1e-6


def test_compression_error_feedback_accumulates():
    x = {"w": jnp.full((256,), 0.003, jnp.float32)}
    ef = None
    total = jnp.zeros((256,))
    for _ in range(50):
        c, ef = comp.compress_tree(x, ef)
        total = total + comp.decompress(*c["w"])
    # with EF the long-run average converges to the true value
    np.testing.assert_allclose(float(total.mean()) / 50, 0.003, rtol=0.05)


def test_decoupled_pod_training_learns():
    from repro.train.decoupled import DecoupledConfig, make_decoupled_round, outer_state_specs

    cfg = get_smoke_config("qwen3-1.7b")
    model = build(cfg, tp=1)
    oc = OptConfig(lr=3e-3, warmup_steps=5, total_steps=200)
    sspecs = state_specs(model, oc)
    n_pods, quantum = 2, 4
    inner = make_train_step(model, oc, accum_steps=1)
    dcfg = DecoupledConfig(quantum=quantum)
    round_fn = jax.jit(make_decoupled_round(model, oc, dcfg, inner, n_pods))
    params0 = model.init(jax.random.PRNGKey(0))
    inner_states = jax.vmap(
        lambda k: {"params": params0, "opt": init_params(k, sspecs["opt"])}
    )(jax.random.split(jax.random.PRNGKey(1), n_pods))
    outer = {"params": params0, "momentum": init_params(jax.random.PRNGKey(2), outer_state_specs(model))}
    dc = DataConfig(cfg.vocab_size, 64, 4, seed=5)
    losses = []
    for r in range(8):
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs).reshape(n_pods, quantum, *xs[0].shape),
            *[batch_at(dc, r * n_pods * quantum + i) for i in range(n_pods * quantum)],
        )
        inner_states, outer, m = round_fn(inner_states, outer, batches)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85, losses
