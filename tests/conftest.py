"""Shared fixtures. NOTE: no XLA_FLAGS here — the main test process sees the
single real CPU device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900):
    """Run a python snippet in a subprocess with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True, timeout=timeout
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr[-3000:]}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
