"""Hybrid dense+spiking workloads on live RISC-V CPUs.

The paper's headline co-simulation scenario: a multicore RISC-V host
driving dense CIM offload *and* a spiking network in one platform, with
the SNN raster injected by a live CPU through tick-addressed
``CIM_REG_SPIKE`` stores and the output counts read back over the dense
mailbox protocol (``CIM_REG_COUNTS``).  The cross-backend sweep lives in
tests/test_conformance.py; this file holds the focused guarantees:

  * the tick-gate regression: CPU-driven injection produces the same
    per-unit spike counters as the pre-scheduled raster path, bit-exactly,
    under every strategy and quantum — if injection ever lands spikes in
    the wrong tick bucket, these comparisons break;
  * deadline violations (late injection, late readback) raise the loud
    ``snn_mmio_late`` RuntimeError on both dispatch paths instead of
    returning round-timing-dependent results;
  * CPU<->CIM MMIO traffic enters the placement cut: the injector
    pseudo-group of ``profile_traffic(injector=True)`` pulls the chatty
    input stripe toward the pinned CPU segment.
"""
import numpy as np
import pytest

from repro import snn
from repro.core import channel as ch
from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import isa
from repro.vp import workloads as vwl

JOB = snn.hybrid_job((16, 12, 8), t_steps=6, rate=0.5, seed=2)


def _run(sim, backend="vmap", quantum=400, fused=None, max_rounds=800):
    cfg, states, pending, meta = sim
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    ctl.run(max_rounds=max_rounds, check_every=2, fused=fused)
    return ctl, meta


# ---------------------------------------------------------------------------
# tick-gate regression: CPU injection must be indistinguishable from the
# pre-scheduled raster — same tick buckets, same counters, every unit


@pytest.mark.parametrize("strategy", ["split", "packed", "auto"])
@pytest.mark.parametrize("quantum", [400, 1000])
def test_cpu_injection_matches_prescheduled_raster(strategy, quantum):
    job = JOB
    # reference: the same network under pre-scheduled raster events
    descs = snn.segmentation_for(job.snn.layers, "uniform", n_segments=2)
    ref_sim = snn.build_snn(job.snn.layers, descs, job.snn.raster,
                            n_ticks=job.snn.n_ticks)
    ref, ref_meta = _run(ref_sim, quantum=32)
    ref_states = ref.result_states()

    hyb, meta = _run(snn.build_hybrid(job, strategy, channel_latency=2000),
                     quantum=quantum)
    st = hyb.result_states()
    # output layer, merged by global neuron id
    np.testing.assert_array_equal(
        snn.output_spike_counts(st, meta), job.snn.expected_counts)
    # every layer's per-neuron counters, unit by unit: identical buckets
    for l, (s_r, k_r) in enumerate(ref_meta["unit_of_layer"]):
        s_h, k_h = meta["unit_of_layer"][l]
        np.testing.assert_array_equal(
            np.asarray(st["cims"]["spike_counts"][s_h, k_h]),
            np.asarray(ref_states["cims"]["spike_counts"][s_r, k_r]),
            err_msg=f"layer {l}: CPU injection broke tick bucketing")
        # (tick counters may differ: the pending readback keeps the hybrid
        # platform ticking to the full horizon, while the CPU-free
        # reference may terminate as soon as the network drains — counts
        # are frozen either way, which is exactly the point)
    assert snn.total_spikes(st) == job.snn.expected_total
    # and the CPU actually read the same counts back into shared DRAM
    o, counts = snn.hybrid_results(st, meta)
    np.testing.assert_array_equal(counts, job.snn.expected_counts)
    np.testing.assert_array_equal(o, job.dense_expected)


def test_injected_spikes_carry_tick_grid_t_avail():
    """The injection path is tick-addressed, not time-addressed: whatever
    the CPU's local clock reads, the MSG_SPIKE lands with t_avail on the
    raster grid — asserted indirectly by placing the driver both local and
    remote to the input unit and requiring identical spike counters."""
    job = JOB
    sims = {s: _run(snn.build_hybrid(job, s, channel_latency=2000))
            for s in ("split", "packed")}
    counts = {}
    for s, (ctl, meta) in sims.items():
        counts[s] = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(counts["split"], counts["packed"])
    np.testing.assert_array_equal(counts["split"], job.snn.expected_counts)


# ---------------------------------------------------------------------------
# deadline violations are loud, never timing-dependent


# near-saturated raster: ~16 events/timestep at ~7 cycles per store cannot
# fit a 64-cycle tick pitch, so tick-0 stores overrun their deadline
DENSE_RASTER_JOB = snn.hybrid_job((16, 12, 8), t_steps=6, rate=1.0, seed=2)


@pytest.mark.parametrize("fused", [False, True])
def test_late_injection_raises_actionable_error(fused):
    sim = snn.build_hybrid(DENSE_RASTER_JOB, "split", tick_period=64,
                           channel_latency=64)
    cfg, states, pending, _ = sim
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=400)
    with pytest.raises(RuntimeError, match=r"late SNN MMIO") as ei:
        ctl.run(max_rounds=800, check_every=2, fused=fused)
    assert "tick_period" in str(ei.value)


def test_late_error_identical_fused_and_per_round():
    msgs = {}
    for fused in (False, True):
        cfg, states, pending, _ = snn.build_hybrid(
            DENSE_RASTER_JOB, "split", tick_period=64, channel_latency=64)
        ctl = Controller(cfg, states, pending, backend="vmap", quantum=400)
        with pytest.raises(RuntimeError) as ei:
            ctl.run(max_rounds=800, check_every=2, fused=fused)
        msgs[fused] = str(ei.value)
    assert msgs[False] == msgs[True]


def test_default_tick_period_covers_dense_rasters():
    """The builder's own sizing (injection_cycles_bound) must keep the same
    dense raster deadline-clean."""
    cfg, states, pending, meta = snn.build_hybrid(DENSE_RASTER_JOB, "split",
                                                  channel_latency=2000)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=1000)
    ctl.run(max_rounds=800, check_every=2)
    o, counts = snn.hybrid_results(ctl.result_states(), meta)
    np.testing.assert_array_equal(counts, DENSE_RASTER_JOB.snn.expected_counts)
    np.testing.assert_array_equal(o, DENSE_RASTER_JOB.dense_expected)


def test_count_readback_past_tick_raises():
    """A CIM_REG_COUNTS request the unit has already ticked past is served
    with whatever the counter holds — round-timing-dependent, so it must
    trip the same loud watermark."""
    job = snn.snn_inference_job((12, 8), t_steps=4, rate=0.6, seed=3)
    descs = snn.segmentation_for(job.layers, "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
    # hand-inject a readback for tick 1 arriving far too late (t_avail deep
    # into the run): by then the unit has ticked past 1
    s, k = meta["out_unit"]
    injected = dict(pending)
    late_t = 6 * 10_000
    for f, v in (("kind", ch.MSG_W_CIM), ("addr", (k << 16) | isa.CIM_REG_COUNTS),
                 ("data", 1), ("t_avail", late_t)):
        injected[f] = injected[f].at[s, -1].set(v)
    injected["valid"] = injected["valid"].at[s, -1].set(True)
    ctl = Controller(cfg, states, injected, backend="vmap", quantum=32)
    with pytest.raises(RuntimeError, match=r"late SNN MMIO"):
        ctl.run(max_rounds=400, check_every=2)


# ---------------------------------------------------------------------------
# CPU<->CIM MMIO traffic enters the placement cut


def test_injector_traffic_pins_input_stripe_to_cpu_segment():
    job = JOB
    layers, raster = job.snn.layers, job.snn.raster
    rates, traffic = snn.profile_traffic(layers, raster,
                                         n_ticks=job.snn.n_ticks,
                                         injector=True)
    g = len(snn.layer_groups(layers))
    assert traffic.shape == (g + 1, g + 1)
    assert len(rates) == g
    # the injector row carries the raster's events/tick into layer 0
    ev_rate = np.count_nonzero(raster) / job.snn.n_ticks
    assert traffic[g, 0] == pytest.approx(ev_rate)
    assert traffic[g, 1:g].sum() == 0
    # the readback column carries the counts DMA out of the output stripe
    assert traffic[g - 1, g] > 0


def test_pinned_injector_pulls_chatty_group_into_cpu_segment():
    # synthetic: only group 2 talks to the injector (pseudo-group 3), and
    # one-slot budgets force the groups apart — the cut is minimized only
    # if group 2 lands in the injector's (pinned) segment
    traffic = np.zeros((4, 4))
    traffic[3, 2] = 10.0  # injector -> group 2 MMIO stream
    assign = sg.traffic_partition([1, 1, 1, 0], [1.0, 1.0, 1.0, 0.0],
                                  traffic, n_segments=4, slots_per_seg=1,
                                  pinned={3: 0})
    assert assign[3] == 0, "pinned pseudo-group moved"
    assert assign[2] == 0, \
        "injection traffic did not pull the chatty group to the CPU segment"
    assert assign[0] != 0 and assign[1] != 0, "one-slot budget violated"


def test_traffic_partition_pinned_respects_budget():
    traffic = np.zeros((3, 3))
    with pytest.raises(AssertionError, match="does not fit"):
        sg.traffic_partition([2, 2, 2], [1.0] * 3, traffic, n_segments=3,
                             slots_per_seg=2, pinned={0: 0, 1: 0})


# ---------------------------------------------------------------------------
# builder plumbing


def test_spike_events_encoding_and_order():
    raster = np.zeros((3, 4), np.int32)
    raster[0, 2] = 1
    raster[2, 0] = 1
    raster[2, 3] = 1
    ev = vwl.spike_events(raster)
    assert ev.tolist() == [isa.pack_spike(0, 2), isa.pack_spike(2, 0),
                           isa.pack_spike(2, 3)]
    with pytest.raises(AssertionError, match="0/1"):
        vwl.spike_events(raster * 2)


def test_build_hybrid_rejects_wide_input_layer():
    wide = snn.hybrid_job((300, 12, 8), t_steps=2, rate=0.1, seed=0)
    with pytest.raises(AssertionError, match="one crossbar|one input tile"):
        snn.build_hybrid(wide, "packed")


def test_build_requires_uniform_tick_period():
    descs = [sg.SegmentDesc(cpu=True, dram=True, n_cims=2, cim_mgr=0)]
    cim_init = {
        0: {"mode": isa.CIM_MODE_SPIKE, "tick_period": 10_000},
        1: {"mode": isa.CIM_MODE_SPIKE, "tick_period": 20_000},
    }
    with pytest.raises(AssertionError, match="tick_period"):
        sg.build(descs, cim_init=cim_init)
