"""Property tests for the paper's core claim (§IV): time-decoupled parallel
execution changes host scheduling, never simulated semantics.

- backend equivalence: sequential / threads / vmap produce bit-identical
  final states for the same quantum;
- decoupling legality: for any quantum <= channel latency, no message is
  ever applied in the receiver's past (asserted by construction + checked
  via the monotone time bound), and the *architectural results* (DRAM
  contents, CIM op counts, instruction counts) are quantum-invariant;
- simulated timing across quanta stays within one quantum of the reference
  (the bounded-staleness error the paper accepts).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

LAYER = wl.Layer("prop", "t", 10, 8, 4)


def build_sim(channel_latency=4096):
    descs = sg.uniform(2, 2)
    job = wl.cim_workload(LAYER, mgr_segments=[0, 1], cim_ids_per_mgr={0: (0, 1), 1: (2, 3)})
    cfg, states, pending = sg.build(
        descs, programs=job["programs"], dram_words=job["dram"],
        crossbars=job["crossbars"], scratch_init=job["scratch"],
        channel_latency=channel_latency,
    )
    return cfg, states, pending, job


def run(backend, quantum, channel_latency=4096, max_rounds=400):
    cfg, states, pending, job = build_sim(channel_latency)
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    ctl.run(max_rounds=max_rounds, check_every=1)
    states = ctl.result_states()
    o = np.asarray(states["dram"]["data"][0][job["o_word"] : job["o_word"] + LAYER.h * LAYER.p])
    return {
        "o": o.reshape(LAYER.h, LAYER.p),
        "expected": job["expected"],
        "times": np.asarray(states["time"]),
        "instrs": np.asarray(states["stats"]["instrs"]),
        "cim_ops": np.asarray(states["cims"]["ops"]),
        "hist": np.asarray(states["stats"]["txn_hist"]).sum(0),
    }


@pytest.fixture(scope="module")
def reference():
    return run("sequential", quantum=2048)


def test_results_correct(reference):
    np.testing.assert_array_equal(reference["o"], reference["expected"])


@pytest.mark.parametrize("backend", ["vmap", "threads"])
def test_backend_bit_identical(reference, backend):
    got = run(backend, quantum=2048)
    np.testing.assert_array_equal(got["o"], reference["o"])
    np.testing.assert_array_equal(got["times"], reference["times"])
    np.testing.assert_array_equal(got["instrs"], reference["instrs"])
    np.testing.assert_array_equal(got["cim_ops"], reference["cim_ops"])
    np.testing.assert_array_equal(got["hist"], reference["hist"])


@settings(max_examples=4, deadline=None)
@given(quantum=st.sampled_from([512, 1024, 4096]))
def test_quantum_invariance_of_results(quantum):
    """Architectural results are identical for any quantum ≤ latency.

    Instruction counts are NOT asserted: poll loops spin until the done-flag
    message is delivered, and delivery lands on quantum boundaries — spin
    iteration counts legitimately vary with N (bounded timing skew, the
    decoupling trade the paper accepts).  The computed results never do.
    """
    ref = run("vmap", quantum=2048)
    got = run("vmap", quantum=quantum)
    np.testing.assert_array_equal(got["o"], ref["o"])
    np.testing.assert_array_equal(got["o"], ref["expected"])
    np.testing.assert_array_equal(got["cim_ops"], ref["cim_ops"])


def test_remote_read_roundtrip():
    """Cross-segment blocking load: CPU1 (no local DRAM) reads a word that
    CPU0's segment owns — exercises MSG_R_DRAM/MSG_R_RESP and CPU stall."""
    descs = [sg.SegmentDesc(cpu=True, dram=True), sg.SegmentDesc(cpu=True)]
    dram = np.zeros(4096, np.int32)
    dram[100] = 4242
    programs = {
        0: "halt",
        1: f"""
            li t1, {100 * 4}
            lw t2, 0(t1)
            li t3, {0x7000_0000}
            sw t2, 0(t3)
            halt
        """,
    }
    cfg, states, pending = sg.build(descs, programs=programs, dram_words=dram, channel_latency=500)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=500)
    ctl.run(max_rounds=50, check_every=1)
    states = ctl.result_states()
    assert int(states["scratch"][1][0]) == 4242
    assert bool(states["cpu"]["halted"].all())


def test_auto_segmentation_balances():
    costs = {"cpu0": 10.0, "cpu1": 1.0, "dram": 3.0, "cim0": 4.0, "cim1": 4.0, "cim2": 4.0, "cim3": 4.0}
    descs = sg.auto_segmentation(costs, 4)
    assert sum(d.n_cims for d in descs) == 4
    assert sum(1 for d in descs if d.cpu) == 2
    assert any(d.dram for d in descs)
    # the heavy cpu0 segment should not also receive CIMs
    heavy = [d for d in descs if d.cpu][0]
    assert heavy.n_cims <= 1
