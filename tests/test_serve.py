"""Fleet serving (serve/snn_serve.py) and the serving-path bugfixes.

The serving contract is bit-exactness: a batched bucket's per-job results
— final states, pending boxes, round counts, watermark errors — must be
bit-identical to running each request solo at the same ``check_every``
cadence, on every backend and both dispatch paths (docs/serving.md).  On
top of the conformance cells this file pins the three serving-path bugs:
``greedy_generate``'s shape-heuristic cache padding, ``Controller.run``
re-entry on a finished controller, and stats/metrics/telemetry
accumulation across multiple ``run()`` calls.
"""
import jax
import numpy as np
import pytest

from repro.core.controller import Controller
from repro.serve.snn_serve import SnnServer, _normalize
from repro.snn import workloads as wl

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

QUANTUM = 10_000
CHECK_EVERY = 4
MAX_ROUNDS = 300
SIZES = (12, 10, 8)


@pytest.fixture(scope="module")
def fleet():
    # 5 requests -> the 8-wide bucket runs with 3 inert padding lanes
    return wl.serve_fleet(5, SIZES, seed=3)


@pytest.fixture(scope="module")
def served(fleet):
    srv = SnnServer(bucket_size=8, check_every=CHECK_EVERY,
                    max_rounds=MAX_ROUNDS, quantum=QUANTUM)
    tickets = [srv.submit(r) for r in fleet]
    return tickets, srv.flush()


def solo(req, backend, fused):
    ctl = Controller(req.cfg, req.states, req.pending, backend=backend,
                     quantum=QUANTUM)
    rounds, _ = ctl.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY,
                        fused=fused)
    return rounds, ctl.result_states()


def assert_states_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# serving conformance: batched == solo, bit for bit


@pytest.mark.parametrize("backend,fused", [
    ("sequential", False), ("threads", False),
    ("vmap", False), ("vmap", True),
])
def test_bucket_matches_solo(fleet, served, backend, fused):
    tickets, results = served
    for t, req in zip(tickets, fleet):
        res = results[t]
        assert res.ok, res.error
        rounds, states = solo(req, backend, fused)
        assert res.rounds == rounds
        assert_states_equal(res.states, states)
        assert res.output_counts().tolist() == list(req.expected_counts)


def test_shard_map_bucket_matches_solo(subproc):
    subproc(
        """
import jax, numpy as np
from repro.core.controller import Controller
from repro.launch.mesh import make_serve_mesh
from repro.serve.snn_serve import SnnServer
from repro.snn import workloads as wl

reqs = wl.serve_fleet(6, (12, 10, 8), seed=11)
srv = SnnServer(bucket_size=8, mesh=make_serve_mesh(), check_every=4,
                max_rounds=300)
tickets = [srv.submit(r) for r in reqs]
res = srv.flush()
solo = wl.serve_fleet(6, (12, 10, 8), seed=11)
for t, req in zip(tickets, solo):
    assert res[t].ok, res[t].error
    ctl = Controller(req.cfg, req.states, req.pending, backend="vmap",
                     quantum=10_000)
    rounds, _ = ctl.run(max_rounds=300, check_every=4)
    assert res[t].rounds == rounds
    for a, b in zip(jax.tree.leaves(ctl.result_states()),
                    jax.tree.leaves(res[t].states)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res[t].output_counts().tolist() == list(req.expected_counts)
print("sharded serving == solo, 6 jobs over 4 devices")
""",
        n_devices=4,
    )


def test_mixed_caps_one_bucket():
    """Pad-compatible caps: one bucket, per-job watermark semantics."""
    ra = wl.serve_request(SIZES, seed=100, in_cap=128, out_cap=64)
    rb = wl.serve_request(SIZES, seed=101, in_cap=256, out_cap=128)
    assert _normalize(ra.cfg) == _normalize(rb.cfg)
    srv = SnnServer(bucket_size=2, check_every=CHECK_EVERY,
                    max_rounds=MAX_ROUNDS)
    ta, tb = srv.submit(ra), srv.submit(rb)
    res = srv.flush()
    assert srv.dispatches >= 1 and len(res) == 2
    for t, req in ((ta, ra), (tb, rb)):
        assert res[t].ok, res[t].error
        rounds, states = solo(req, "vmap", True)
        assert res[t].rounds == rounds
        assert_states_equal(res[t].states, states)


def test_per_job_fault_seeds_one_bucket():
    """Different FaultConfig seeds batch together (the seed rides the
    stacked state, not the compiled program) and reproduce their solo
    faulted runs bit for bit."""
    from repro.faults import FaultConfig

    build = lambda: [
        wl.serve_request(SIZES, seed=7, t_steps=6,
                         faults=FaultConfig(seed=s, p_spike_drop=0.1))
        for s in (1, 2)
    ]
    reqs = build()
    assert reqs[0].cfg != reqs[1].cfg  # seeds differ in cfg...
    assert _normalize(reqs[0].cfg) == _normalize(reqs[1].cfg)  # ...not in key
    srv = SnnServer(bucket_size=2, check_every=CHECK_EVERY,
                    max_rounds=MAX_ROUNDS)
    tickets = [srv.submit(r) for r in reqs]
    res = srv.flush()
    for t, req in zip(tickets, build()):
        assert res[t].ok, res[t].error
        rounds, states = solo(req, "vmap", True)
        assert res[t].rounds == rounds
        assert_states_equal(res[t].states, states)


def _overflowing_request():
    """A request whose traffic overflows its own (tiny) inbox cap mid-run:
    the raster passes the build-time check (small input layer) but the
    wide hidden layer's one-tick fan-out exceeds in_cap.  Seed 13 is a
    known hit; the loop keeps the recipe robust to builder drift."""
    for t_steps in (2, 3):
        for seed in (13, *range(20)):
            try:
                build = lambda: wl.serve_request(
                    (8, 64, 8), t_steps=t_steps, rate=0.9, seed=seed,
                    in_cap=48)
                req = build()
            except AssertionError:
                continue
            try:
                solo(req, "vmap", True)
            except RuntimeError as e:
                return build(), str(e)
    pytest.skip("no overflowing workload found in the search budget")


def test_overflow_is_per_request_not_per_bucket():
    """One job's watermark abort becomes ok=False with the SOLO error
    message (same caps, same true-demand watermark); its bucket mates
    still complete exactly."""
    bad, solo_msg = _overflowing_request()
    # co-bucket the bad job with a healthy same-topology neighbor (shared
    # compiled shape) and a different-topology job (its own bucket)
    mate = wl.serve_request((8, 64, 8), t_steps=2, rate=0.2, seed=1000,
                            in_cap=256)
    good = wl.serve_request(SIZES, seed=5)
    srv = SnnServer(bucket_size=4, check_every=CHECK_EVERY,
                    max_rounds=MAX_ROUNDS)
    tb, tm, tg = srv.submit(bad), srv.submit(mate), srv.submit(good)
    res = srv.flush()
    assert not res[tb].ok
    assert res[tb].error == solo_msg, (res[tb].error, solo_msg)
    assert res[tm].ok, res[tm].error
    assert res[tm].output_counts().tolist() == list(mate.expected_counts)
    assert res[tg].ok and (res[tg].output_counts().tolist()
                           == list(good.expected_counts))


def test_padding_lanes_are_inert(fleet, served):
    """5 jobs in an 8-wide bucket: identical results at exact width."""
    tickets, results = served
    srv = SnnServer(bucket_size=5, check_every=CHECK_EVERY,
                    max_rounds=MAX_ROUNDS)
    fleet2 = wl.serve_fleet(5, SIZES, seed=3)
    t2 = [srv.submit(r) for r in fleet2]
    res2 = srv.flush()
    for a, b in zip(tickets, t2):
        assert results[a].rounds == res2[b].rounds
        assert_states_equal(results[a].states, res2[b].states)


def test_serve_with_telemetry(fleet):
    """Per-job trace rings: events drain per request, and tracing is
    bit-invisible to the served results."""
    from repro.obs import TraceConfig

    srv = SnnServer(bucket_size=8, check_every=CHECK_EVERY,
                    max_rounds=MAX_ROUNDS, obs=TraceConfig(capacity=4096))
    fleet2 = wl.serve_fleet(5, SIZES, seed=3)
    tickets = [srv.submit(r) for r in fleet2]
    res = srv.flush()
    for t, req in zip(tickets, fleet):
        assert res[t].ok, res[t].error
        rounds, states = solo(req, "vmap", True)
        assert res[t].rounds == rounds
        # traced state minus the ring == untraced state
        untraced = {k: v for k, v in res[t].states.items() if k != "trace"}
        assert_states_equal(untraced, states)
        assert len(res[t].events) > 0
        assert res[t].trace_lost == 0


if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(1, 6), seed=st.integers(0, 50),
           bucket=st.sampled_from([2, 4, 8]))
    def test_property_batched_equals_solo(n, seed, bucket):
        reqs = wl.serve_fleet(n, SIZES, seed=seed)
        srv = SnnServer(bucket_size=bucket, check_every=CHECK_EVERY,
                        max_rounds=MAX_ROUNDS)
        tickets = [srv.submit(r) for r in reqs]
        res = srv.flush()
        for t, req in zip(tickets, wl.serve_fleet(n, SIZES, seed=seed)):
            assert res[t].ok, res[t].error
            rounds, states = solo(req, "vmap", True)
            assert res[t].rounds == rounds
            assert_states_equal(res[t].states, states)


# ---------------------------------------------------------------------------
# bugfix: Controller.run re-entry on a finished controller


@pytest.mark.parametrize("backend,fused", [
    ("sequential", False), ("threads", False),
    ("vmap", False), ("vmap", True),
])
def test_run_reentry_is_free(backend, fused):
    req = wl.serve_request(SIZES, seed=3)
    ctl = Controller(req.cfg, req.states, req.pending, backend=backend,
                     quantum=QUANTUM)
    rounds, _ = ctl.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY,
                        fused=fused)
    before = (rounds, ctl.dispatches, ctl.dispatch_syncs,
              ctl.sim_time().copy(), ctl.result_states())
    rounds2, _ = ctl.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY,
                         fused=fused)
    assert rounds2 == rounds
    assert ctl.rounds_run == rounds
    assert ctl.dispatches == before[1]       # no dispatch burned
    assert ctl.dispatch_syncs == before[2]   # no extra host sync
    np.testing.assert_array_equal(ctl.sim_time(), before[3])
    assert_states_equal(ctl.result_states(), before[4])


def test_run_reentry_continues_unfinished():
    """The short-circuit must key on CLEAN termination, not on having run:
    a partial run (max_rounds hit early) must continue when re-entered —
    that is the serving loop's incremental-run flow."""
    req = wl.serve_request(SIZES, seed=3)
    ctl = Controller(req.cfg, req.states, req.pending, backend="vmap",
                     quantum=QUANTUM)
    ctl.run(max_rounds=CHECK_EVERY, check_every=CHECK_EVERY)
    assert not ctl._finished
    rounds, _ = ctl.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY)
    ref, states = solo(req, "vmap", True)
    assert rounds == ref
    assert_states_equal(ctl.result_states(), states)


# ---------------------------------------------------------------------------
# bugfix audit: stats()/metrics()/telemetry across multiple run() calls


def test_counters_accumulate_across_runs():
    """Counters are cumulative device state: a run split in two at a
    check_every boundary reports the same stats/metrics as one continuous
    run, and reading them twice does not perturb them."""
    req = wl.serve_request(SIZES, seed=3)
    one = Controller(req.cfg, req.states, req.pending, backend="vmap",
                     quantum=QUANTUM)
    one.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY)
    two = Controller(req.cfg, req.states, req.pending, backend="vmap",
                     quantum=QUANTUM)
    two.run(max_rounds=CHECK_EVERY, check_every=CHECK_EVERY)
    two.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY)
    assert one.rounds_run == two.rounds_run

    def assert_tree_equal(a, b):
        la, ta = jax.tree.flatten(a)
        lb, tb = jax.tree.flatten(b)
        assert ta == tb
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    sa = one.stats()
    assert_tree_equal(sa, two.stats())
    assert_tree_equal(one.metrics(), two.metrics())
    # reading is non-destructive
    assert_tree_equal(one.stats(), sa)


@pytest.mark.parametrize("fused", [False, True])
def test_telemetry_not_double_counted_across_runs(fused):
    """Drained events accumulate exactly once: split run == single run in
    total event count, and a re-entered finished run drains nothing new."""
    from repro.obs import TraceConfig

    def build(obs):
        req = wl.serve_request(SIZES, seed=3)
        return Controller(req.cfg, req.states, req.pending, backend="vmap",
                          quantum=QUANTUM, obs=obs)

    one = build(TraceConfig(capacity=4096))
    one.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY, fused=fused)
    two = build(TraceConfig(capacity=4096))
    two.run(max_rounds=CHECK_EVERY, check_every=CHECK_EVERY, fused=fused)
    two.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY, fused=fused)
    ea, eb = one.trace_events(), two.trace_events()
    assert len(ea) == len(eb) > 0
    order = list(ea.dtype.names)
    np.testing.assert_array_equal(np.sort(ea, order=order),
                                  np.sort(eb, order=order))
    n = len(eb)
    two.run(max_rounds=MAX_ROUNDS, check_every=CHECK_EVERY, fused=fused)
    assert len(two.trace_events()) == n  # re-entry drained nothing new


# ---------------------------------------------------------------------------
# bugfix: greedy_generate cache padding driven by cache_specs


def _toy_model(arch):
    from repro.configs import get_smoke_config
    from repro.models.model import build

    cfg = get_smoke_config(arch)
    model = build(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_pad_to_ssm_batch_equals_seq_collision():
    """SSM cache cells carry the BATCH axis where a KV cell keeps its
    sequence axis; with batch == prompt_len the old ``x.shape[-3] == seq``
    heuristic padded the batch.  The specs-driven axis map knows an SSM
    cache has no sequence axis at all, so pad_to must be a no-op."""
    from repro.serve.serve_step import cache_seq_axes, greedy_generate

    cfg, model, params = _toy_model("falcon-mamba-7b")
    seq = batch = 16  # the collision
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (batch, seq),
                                      0, cfg.vocab_size)}
    cache, _ = model.prefill(params, b)
    assert all(a is None for a in cache_seq_axes(cfg, cache, seq, batch))
    t_nopad = greedy_generate(model, params, b, steps=4)
    t_pad = greedy_generate(model, params, b, steps=4, pad_to=seq + 4)
    np.testing.assert_array_equal(np.asarray(t_nopad), np.asarray(t_pad))


def test_pad_to_dense_finds_seq_axis_despite_collision():
    """Dense KV cells: the sequence axis is found from the specs even when
    batch == seq makes every axis-size heuristic ambiguous, and the
    padding amount is inert (decode masks past ``pos``)."""
    from repro.serve.serve_step import cache_seq_axes, greedy_generate

    cfg, model, params = _toy_model("qwen3-1.7b")
    seq = batch = 16
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (batch, seq),
                                      0, cfg.vocab_size)}
    cache, _ = model.prefill(params, b)
    axes = cache_seq_axes(cfg, cache, seq, batch)
    assert all(ax == leaf.ndim - 3
               for ax, leaf in zip(axes, jax.tree.leaves(cache)))
    t1 = greedy_generate(model, params, b, steps=4, pad_to=seq + 4)
    t2 = greedy_generate(model, params, b, steps=4, pad_to=seq + 9)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_pad_to_encdec_cross_cache_stays_unpadded():
    """The encdec cross cache is fixed-length memory (kind="decode" probes
    at the native audio-frame length) — padding it would perturb every
    cross-attention read.  Self caches pad, cross caches must not."""
    import jax.numpy as jnp

    from repro.serve.serve_step import cache_seq_axes, greedy_generate

    cfg, model, params = _toy_model("whisper-tiny")
    seq = batch = 16
    key = jax.random.PRNGKey(1)
    b = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
         "enc_feats": jax.random.normal(key, (batch, seq, cfg.d_model),
                                        jnp.bfloat16)}
    cache, _ = model.prefill(params, b)
    axes = cache_seq_axes(cfg, cache, seq, batch)
    # flatten order: "cross" < "self" — cross leaves first, unpadded
    assert axes[:2] == [None, None] and None not in axes[2:]
    t1 = greedy_generate(model, params, b, steps=4, pad_to=seq + 4)
    t2 = greedy_generate(model, params, b, steps=4, pad_to=seq + 9)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
