"""Equivalence tests for the §Perf beyond-paper execution paths:
flash train attention (custom VJP) and the decode MoE token-replication
path must match their reference implementations."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def test_flash_train_matches_dense_fwd_bwd():
    key = jax.random.PRNGKey(0)
    b, s, h, d = 2, 512, 2, 32
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d), jnp.float32)

    o_d = L.dense_attention(q, k, v, causal=True)
    o_f = L.flash_attention_train(q, k, v, 128, 128)
    np.testing.assert_allclose(np.asarray(o_d), np.asarray(o_f), atol=2e-5)

    def make_loss(fn):
        return lambda q, k, v: (fn(q, k, v) * (q + 1)).sum()

    gd = jax.grad(make_loss(lambda q, k, v: L.dense_attention(q, k, v, causal=True)),
                  argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(make_loss(lambda q, k, v: L.flash_attention_train(q, k, v, 128, 128)),
                  argnums=(0, 1, 2))(q, k, v)
    for name, a, b2 in zip("qkv", gd, gf):
        rel = float(jnp.abs(a - b2).max() / (jnp.abs(a).max() + 1e-9))
        assert rel < 1e-4, (name, rel)


def test_flash_in_model_matches_dense_in_model():
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.model import build

    cfg = get_smoke_config("qwen3-1.7b")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 512), 0, cfg.vocab_size)}
    losses = {}
    for impl in ("dense", "flash"):
        c = dataclasses.replace(cfg, attn_impl=impl)
        m = build(c, tp=1)
        params = m.init(jax.random.PRNGKey(0))
        losses[impl], _ = m.loss(params, batch)
    np.testing.assert_allclose(float(losses["dense"]), float(losses["flash"]), rtol=2e-3)


def test_moe_decode_path_matches_dense(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro import compat
from repro.configs import get_smoke_config
from repro.models.moe import apply_moe
from repro.models.transformer import decoder_specs
from repro.models.moe import moe_specs
from repro.common import init_params, DTypePolicy

cfg = get_smoke_config("kimi-k2-1t-a32b")
cfg = dataclasses.replace(cfg, d_model=64)
mesh = compat.make_mesh((2, 2), ("data", "model"))
specs = moe_specs(cfg, tp=2)
params = init_params(jax.random.PRNGKey(0), specs)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 64), jnp.float32)  # decode shape
pol = DTypePolicy()
y_ref, _ = apply_moe(cfg, params, x, pol, mesh=None)
with compat.set_mesh(mesh):
    y_dec, _ = jax.jit(lambda p, x: apply_moe(cfg, p, x, pol, mesh=mesh, decode=True))(params, x)
np.testing.assert_allclose(np.asarray(y_dec, np.float32), np.asarray(y_ref, np.float32),
                           rtol=2e-2, atol=2e-2)
print("moe decode path OK", float(jnp.abs(y_dec - y_ref).max()))
""",
        n_devices=4,
    )


def test_ssm_chunked_restructure_matches_kernel_ref():
    """mamba1 per-chunk expansion (hillclimb) still equals the plain scan."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.ssm import mamba1_block, mamba1_specs
    from repro.common import init_params, DTypePolicy

    cfg = get_smoke_config("falcon-mamba-7b")
    p = init_params(jax.random.PRNGKey(0), mamba1_specs(cfg, tp=1))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32) * 0.1
    pol = DTypePolicy()
    y, st = mamba1_block(cfg, p, x, pol)
    # step-by-step decode over the same inputs must match the chunked result
    import jax as _jax

    st2 = None
    outs = []
    for t in range(8):
        xt = x[:, t : t + 1]
        if st2 is None:
            din = cfg.ssm.expand * cfg.d_model
            st2 = {
                "conv": jnp.zeros((2, cfg.ssm.d_conv - 1, din), jnp.bfloat16),
                "ssm": jnp.zeros((2, din, cfg.ssm.d_state), jnp.float32),
            }
        yt, st2 = mamba1_block(cfg, p, xt, pol, state=st2)
        outs.append(yt)
    step_y = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_y, np.float32), np.asarray(y[:, :8], np.float32), atol=3e-2
    )
