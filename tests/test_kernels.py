"""Per-kernel allclose vs pure-jnp oracles, with hypothesis shape/value
sweeps (interpret mode executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.kernels.crossbar_vmm import ops as xb_ops
from repro.kernels.crossbar_vmm import ref as xb_ref
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.kernels.ssm_scan import ref as ssm_ref


@settings(max_examples=12, deadline=None)
@given(
    r=st.integers(1, 300),
    c=st.integers(1, 300),
    in_res=st.sampled_from([2, 4, 8]),
    out_res=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_crossbar_kernel_matches_ref(r, c, in_res, out_res, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(-128, 128, (r, c)), jnp.int8)
    x = jnp.asarray(rng.integers(-(1 << 12), 1 << 12, (c,)), jnp.int32)  # exercises DAC clamp
    ref = xb_ref.crossbar_vmm(w, x, in_res, out_res)
    ker = xb_ops.crossbar_vmm(w, x, in_res, out_res)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


def test_crossbar_equals_exact_int_math():
    rng = np.random.default_rng(0)
    w = rng.integers(-128, 128, (256, 256)).astype(np.int8)
    x = rng.integers(-100, 100, (256,)).astype(np.int32)
    got = np.asarray(xb_ref.crossbar_vmm(jnp.asarray(w), jnp.asarray(x), 8, 8))
    exact = np.clip(w.astype(np.int64) @ np.clip(x, -128, 127), -(1 << 15), (1 << 15) - 1)
    np.testing.assert_array_equal(got, exact)


def test_crossbar_adc_saturates():
    w = jnp.full((4, 256), 127, jnp.int8)
    x = jnp.full((256,), 127, jnp.int32)
    out = np.asarray(xb_ref.crossbar_vmm(w, x, 8, 8))
    assert (out == (1 << 15) - 1).all()  # 127*127*256 ≫ ADC full scale


def test_crossbar_matmul_tiled():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.integers(-16, 16, (100, 70)), jnp.int8)
    x = jnp.asarray(rng.integers(-50, 50, (70, 9)), jnp.int32)
    ref = xb_ref.crossbar_matmul(w, x)
    ker = xb_ops.crossbar_matmul(w, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


@settings(max_examples=6, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([64, 128, 192]),
    d=st.sampled_from([128, 256]),
    n=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ssm_scan_kernel_matches_ref(b, s, d, n, seed):
    rng = np.random.default_rng(seed)
    da = jnp.asarray(rng.uniform(0.5, 0.999, (b, s, d, n)), jnp.float32)
    dbx = jnp.asarray(rng.normal(0, 0.2, (b, s, d, n)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 1.0, (b, s, n)), jnp.float32)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_ref, _ = ssm_ref.selective_scan(da, dbx, c, h0)
    y_ker = ssm_ops.ssm_scan(da, dbx, c)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker), rtol=1e-5, atol=1e-5)
