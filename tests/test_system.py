"""End-to-end behaviour tests for the paper's system: full VP runs of a
Table III layer (scaled) in both execution modes, on both segmentations,
checking architectural results and the headline speedup machinery."""
import numpy as np
import pytest

from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

LAYER = wl.TABLE_III[1].scaled(8)  # Googlenet-conv2 / 8 -> (7, 7, 1)-ish


def _final_o(ctl, job, layer):
    st = ctl.result_states()
    o = np.asarray(st["dram"]["data"][0][job["o_word"] : job["o_word"] + layer.h * layer.p])
    return o.reshape(layer.h, layer.p)


def test_riscv_mode_uniform():
    layer = wl.Layer("sys", "riscv", 16, 12, 3)
    job = wl.riscv_workload(layer)
    cfg, states, pending = sg.build(
        sg.uniform(2, 2), programs=job["programs"], dram_words=job["dram"]
    )
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=4096)
    ctl.run(max_rounds=300, check_every=1)
    np.testing.assert_array_equal(_final_o(ctl, job, layer), job["expected"])
    stats = ctl.stats()
    expected_misses = (layer.h * layer.w + layer.w * layer.p + layer.h * layer.p) / 8
    assert stats["dram"]["reads"].sum() >= expected_misses * 0.5  # compulsory misses
    assert stats["cache"]["d_hits"].sum() > 0


@pytest.mark.parametrize("strategy", ["uniform", "load_oriented"])
def test_cim_mode_both_segmentations(strategy):
    layer = wl.Layer("sys", "cim", 20, 16, 6)
    if strategy == "uniform":
        descs = sg.uniform(2, 2)
        mgrs, ids = [0, 1], {0: (0, 1), 1: (2, 3)}
    else:
        descs = sg.load_oriented()  # CIMs in segments 2/3, managed by CPU1
        mgrs, ids = [1], {1: (0, 2)}  # one unit from each CIM segment
    job = wl.cim_workload(layer, mgr_segments=mgrs, cim_ids_per_mgr=ids,
                          ordinals=sg.mailbox_ordinals(descs))
    cfg, states, pending = sg.build(
        descs, programs=job["programs"], dram_words=job["dram"],
        crossbars=job["crossbars"], scratch_init=job["scratch"], channel_latency=5000,
    )
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=5000)
    ctl.run(max_rounds=400, check_every=1)
    np.testing.assert_array_equal(_final_o(ctl, job, layer), job["expected"])
    assert ctl.stats()["cim_ops"].sum() == layer.p


def test_cim_kernel_path_matches_ref_path():
    """use_kernel=True routes the crossbar math through the Pallas kernel."""
    layer = wl.Layer("sys", "k", 12, 10, 4)
    descs = sg.uniform(2, 2)
    job = wl.cim_workload(layer, mgr_segments=[0, 1], cim_ids_per_mgr={0: (0, 1), 1: (2, 3)})
    results = []
    for use_kernel in (False, True):
        cfg, states, pending = sg.build(
            descs, programs=job["programs"], dram_words=job["dram"],
            crossbars=job["crossbars"], scratch_init=job["scratch"],
            channel_latency=4000, use_kernel=use_kernel,
        )
        ctl = Controller(cfg, states, pending, backend="vmap", quantum=4000)
        ctl.run(max_rounds=300, check_every=1)
        results.append(_final_o(ctl, job, layer))
    np.testing.assert_array_equal(results[0], results[1])
    np.testing.assert_array_equal(results[0], job["expected"])


def test_transaction_tracing_histogram():
    layer = wl.Layer("sys", "tr", 8, 8, 2)
    descs = sg.load_oriented()
    job = wl.cim_workload(layer, mgr_segments=[1], cim_ids_per_mgr={1: (0, 2)},
                          ordinals=sg.mailbox_ordinals(descs))
    cfg, states, pending = sg.build(
        descs, programs=job["programs"], dram_words=job["dram"],
        crossbars=job["crossbars"], scratch_init=job["scratch"], channel_latency=3000,
    )
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=3000)
    ctl.run(max_rounds=300, check_every=1)
    hist = ctl.stats()["txn_histogram"]
    # offload traffic: CIM register writes + scratch DMA + posted DRAM writes
    assert hist[1] > 0 and hist[2] > 0 and hist[0] > 0, hist
