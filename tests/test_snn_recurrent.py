"""Recurrent & lateral SNN connectivity vs the cycle-aware oracle.

The headline property extends the feed-forward invariant to cyclic
networks: lateral synapses (``SNNLayer.lateral``) and backward projections
(``RecurrentEdge``) ride the identical tick-bucketed AER machinery — a
spike emitted at tick k integrates at the destination's tick k+1 whatever
direction the edge points — so a cyclic network simulated on the VP over a
bounded tick horizon (``n_ticks`` -> per-unit ``tick_limit``) produces
spike counts *bit-identical* to the cycle-aware pure-jnp oracle, under
every segmentation strategy, controller backend, quantum, dispatch mode,
and LIF execution path.
"""
import jax
import numpy as np
import pytest

from repro import snn
from repro.core.controller import Controller


def _run_vp(job, descs, placement=None, backend="vmap", quantum=32,
            use_kernel=False, max_rounds=400, fused=None, check_every=1):
    cfg, states, pending, meta = snn.build_snn(
        job.layers, descs, job.raster, edges=job.edges, n_ticks=job.n_ticks,
        placement=placement, use_kernel=use_kernel)
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    ctl.run(max_rounds=max_rounds, check_every=check_every, fused=fused)
    return cfg, ctl, meta


# ---------------------------------------------------------------------------
# connectivity table


def test_connectivity_axon_spaces():
    layers, edges = snn.random_recurrent_snn((24, 20, 6), seed=0)
    in_edges, out_edges, eff_n_in = snn.connectivity(layers, edges)
    # hidden: ff(24) + lateral(20) + feedback(6); output: ff(20) + WTA(6)
    assert eff_n_in == [24 + 20 + 6, 20 + 6]
    assert [(s, o) for s, _, o in in_edges[0]] == [(-1, 0), (0, 24), (1, 44)]
    assert [(s, o) for s, _, o in in_edges[1]] == [(0, 0), (1, 20)]
    # out-edges mirror in-edges: hidden feeds itself + output; output feeds
    # itself (WTA) + hidden (feedback)
    assert sorted(out_edges[0]) == [(0, 24), (1, 0)]
    assert sorted(out_edges[1]) == [(0, 44), (1, 20)]
    assert snn.is_cyclic(layers, edges)
    assert not snn.is_cyclic(snn.random_snn((16, 8)))


def test_connectivity_rejects_bad_edges():
    layers = snn.random_snn((16, 12, 8), seed=1)
    with pytest.raises(AssertionError, match="must name layers"):
        snn.connectivity(layers, (snn.RecurrentEdge(0, 2, np.zeros((8, 12), np.int8)),))
    with pytest.raises(AssertionError, match="must be"):
        snn.connectivity(layers, (snn.RecurrentEdge(1, 0, np.zeros((3, 3), np.int8)),))
    with pytest.raises(AssertionError, match="lateral"):
        bad = snn.SNNLayer(np.zeros((8, 4), np.int8), lateral=np.zeros((4, 8), np.int8))
        snn.connectivity([bad])
    # forward edges (dst > src) are legal since the skip-connection support:
    # this parallel 0 -> 1 projection wires as an extra in-edge, acyclically
    in_edges, _, _ = snn.connectivity(
        layers, (snn.RecurrentEdge(0, 1, np.zeros((8, 12), np.int8)),))
    assert len(in_edges[1]) == 2
    assert not snn.is_cyclic(
        layers, (snn.RecurrentEdge(0, 1, np.zeros((8, 12), np.int8)),))


def test_cyclic_without_horizon_rejected():
    layers, edges = snn.random_recurrent_snn((16, 12, 6), seed=2)
    raster = snn.rate_encode(np.full(16, 0.5), 4, seed=0)
    descs = snn.segmentation_for(layers, "uniform", n_segments=2, edges=edges)
    with pytest.raises(AssertionError, match="n_ticks"):
        snn.build_snn(layers, descs, raster, edges=edges)  # no horizon
    with pytest.raises(AssertionError, match="n_ticks|horizon"):
        snn.oracle_run(layers, raster, edges=edges)
    with pytest.raises(AssertionError, match="horizon"):
        snn.build_snn(layers, descs, raster, edges=edges, n_ticks=2)  # < T


# ---------------------------------------------------------------------------
# hand-checked delay semantics


def test_lateral_self_excitation_fires_every_tick():
    """Identity self-excitation: one seed spike at tick 0 re-excites the
    neuron exactly one tick later, forever — the run fires at every tick of
    the horizon and still terminates (tick_limit), proving both the
    one-tick lateral delay and the bounded-horizon drain."""
    n, horizon = 4, 7
    layers = [snn.SNNLayer(np.eye(n, dtype=np.int8) * 10,
                           snn.LIFParams(thresh=10, leak=0),
                           lateral=np.eye(n, dtype=np.int8) * 10)]
    raster = np.zeros((1, n), np.int32)
    raster[0, 1] = 1
    counts, totals = snn.oracle_run(layers, raster, n_ticks=horizon)
    np.testing.assert_array_equal(counts, [0, horizon, 0, 0])
    descs = snn.segmentation_for(layers, "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(layers, descs, raster,
                                               n_ticks=horizon)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.run(max_rounds=200, check_every=1)
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta), counts)
    assert ctl.done(), "self-sustaining net must still drain at the horizon"
    s, k = meta["out_unit"]
    assert int(np.asarray(st["cims"]["ticks"][s, k])) == horizon


def test_winner_take_all_lateral_inhibition():
    """Two mutually inhibiting neurons, one driven harder: the winner keeps
    firing, the loser is suppressed from tick 1 on (inhibition arrives one
    tick after the winner's first spike)."""
    w = np.eye(2, dtype=np.int8) * 10
    lat = np.array([[0, -10], [-10, 0]], np.int8)
    layers = [snn.SNNLayer(w, snn.LIFParams(thresh=10, leak=0), lateral=lat)]
    t_steps = 6
    raster = np.zeros((t_steps, 2), np.int32)
    raster[:, 0] = 2  # winner driven at 2x threshold
    raster[:, 1] = 1  # loser at exactly threshold
    counts, _ = snn.oracle_run(layers, raster, n_ticks=t_steps + 2)
    # tick 0: both fire (no inhibition yet); from tick 1 the winner's
    # inhibition cancels the loser's drive while the winner shrugs off -10
    # against +20
    np.testing.assert_array_equal(counts, [t_steps, 1])
    descs = snn.segmentation_for(layers, "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(layers, descs, raster,
                                               n_ticks=t_steps + 2)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.run(max_rounds=200, check_every=1)
    np.testing.assert_array_equal(
        snn.output_spike_counts(ctl.result_states(), meta), counts)


def test_backward_edge_is_one_tick_delayed():
    """Layer 1 -> layer 0 feedback: a spike of layer 1 at tick k charges
    layer 0 at tick k+1, verified against a hand-computed schedule."""
    # layer 0: one neuron, fires when driven; layer 1: relay of layer 0
    w0 = np.array([[10]], np.int8)
    w1 = np.array([[10]], np.int8)
    fb = np.array([[10]], np.int8)  # layer1 -> layer0, drive == thresh
    layers = [snn.SNNLayer(w0, snn.LIFParams(thresh=10, leak=0)),
              snn.SNNLayer(w1, snn.LIFParams(thresh=10, leak=0))]
    edges = (snn.RecurrentEdge(src=1, dst=0, weights=fb),)
    raster = np.zeros((1, 1), np.int32)
    raster[0, 0] = 1  # single seed spike
    horizon = 9
    counts, totals = snn.oracle_run(layers, raster, edges=edges, n_ticks=horizon)
    # schedule: L0 fires at 0 -> L1 at 1 -> (feedback) L0 at 2 -> L1 at 3 ...
    # L0 fires at even ticks, L1 at odd ticks, through the horizon
    assert int(totals[0]) == (horizon + 1) // 2
    assert int(counts[0]) == horizon // 2
    descs = snn.segmentation_for(layers, "load_oriented", n_segments=4, edges=edges)
    cfg, states, pending, meta = snn.build_snn(layers, descs, raster,
                                               edges=edges, n_ticks=horizon)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.run(max_rounds=200, check_every=1)
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta), counts)
    assert snn.total_spikes(st) == int(totals.sum())


# ---------------------------------------------------------------------------
# acceptance: the recurrent job across segmentation x backend x quantum


RJOB = snn.snn_recurrent_job((48, 40, 12), t_steps=10, rate=0.5, seed=1)


def test_recurrent_job_exercises_every_cycle_kind():
    """The canonical job must actually spike through all three cyclic
    paths, or the equivalence sweep proves nothing."""
    assert RJOB.layers[-2].lateral is not None  # Elman hidden
    assert RJOB.layers[-1].lateral is not None  # WTA output
    assert len(RJOB.edges) == 1 and RJOB.edges[0].dst < RJOB.edges[0].src
    assert RJOB.expected_total > 0
    totals_per_layer = snn.oracle_rates(
        RJOB.layers, RJOB.raster, edges=RJOB.edges, n_ticks=RJOB.n_ticks)[0]
    assert all(t.sum() > 0 for t in totals_per_layer), \
        "every layer (hence every cycle) must carry spikes"


@pytest.mark.parametrize("strategy", ["uniform", "load_oriented", "auto"])
def test_recurrent_matches_oracle_per_strategy(strategy):
    if strategy == "auto":
        descs, placement = snn.auto_segmentation_for(
            RJOB.layers, n_segments=3, edges=RJOB.edges)
    else:
        descs = snn.segmentation_for(RJOB.layers, strategy, n_segments=4,
                                     edges=RJOB.edges)
        placement = None
    cfg, ctl, meta = _run_vp(RJOB, descs, placement)
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                  RJOB.expected_counts)
    assert snn.total_spikes(st) == RJOB.expected_total


def test_recurrent_backends_bit_identical():
    descs = snn.segmentation_for(RJOB.layers, "uniform", n_segments=4,
                                 edges=RJOB.edges)
    res = {}
    for backend in ("sequential", "vmap", "threads"):
        cfg, ctl, meta = _run_vp(RJOB, descs, backend=backend)
        res[backend] = ctl.result_states()
        ctl.close()
    for backend in ("vmap", "threads"):
        for a, b in zip(jax.tree.leaves(res["sequential"]),
                        jax.tree.leaves(res[backend])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recurrent_quantum_and_dispatch_invariance():
    descs = snn.segmentation_for(RJOB.layers, "uniform", n_segments=4,
                                 edges=RJOB.edges)
    ref = None
    for quantum in (16, 64):
        for fused in (False, True):
            cfg, ctl, meta = _run_vp(RJOB, descs, quantum=quantum, fused=fused,
                                     check_every=2)
            got = snn.output_spike_counts(ctl.result_states(), meta)
            if ref is None:
                ref = got
            np.testing.assert_array_equal(got, ref,
                                          err_msg=f"q={quantum} fused={fused}")
    np.testing.assert_array_equal(ref, RJOB.expected_counts)


def test_recurrent_kernel_path_matches_ref_path():
    descs = snn.segmentation_for(RJOB.layers, "uniform", n_segments=4,
                                 edges=RJOB.edges)
    outs = []
    for use_kernel in (False, True):
        cfg, ctl, meta = _run_vp(RJOB, descs, use_kernel=use_kernel)
        outs.append(snn.output_spike_counts(ctl.result_states(), meta))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], RJOB.expected_counts)


def test_recurrent_shard_map_matches_vmap(subproc):
    """Cyclic spike traffic over the shard_map backend == vmap, bit-exact
    (multi-device subprocess, same pattern as test_distributed.py)."""
    subproc(
        """
import jax, numpy as np
from repro import compat, snn
from repro.core.controller import Controller

job = snn.snn_recurrent_job((24, 20, 8), t_steps=8, rate=0.5, seed=3)
descs = snn.segmentation_for(job.layers, "uniform", n_segments=2, edges=job.edges)
cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster,
                                           edges=job.edges, n_ticks=job.n_ticks)
mesh = compat.make_mesh((2,), ("segment",))
res = {}
for backend, kw in (("vmap", {}), ("shard_map", {"mesh": mesh})):
    ctl = Controller(cfg, states, pending, backend=backend, quantum=32, **kw)
    ctl.run(max_rounds=200, check_every=1)
    res[backend] = ctl.result_states()
for a, b in zip(jax.tree.leaves(res["vmap"]), jax.tree.leaves(res["shard_map"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
np.testing.assert_array_equal(
    snn.output_spike_counts(res["shard_map"], meta), job.expected_counts)
print("shard_map recurrent == vmap OK")
""",
        n_devices=2,
    )


# ---------------------------------------------------------------------------
# wide recurrent layers: stripes + column groups + cyclic fan-out


def test_wide_recurrent_layer_matches_oracle():
    """A 300-neuron laterally-inhibiting hidden layer: 2 row stripes whose
    effective fan-in (48 ff + 300 lateral + 10 feedback) tiles into
    2-slot column groups; lateral spikes fan out to *both* stripes and the
    result still equals the unsharded oracle bit-for-bit."""
    rng = np.random.default_rng(7)
    n0, n1, n2 = 48, 300, 10
    layers = [
        snn.SNNLayer(rng.integers(-4, 8, (n1, n0)).astype(np.int8),
                     snn.LIFParams(thresh=n0, leak=1),
                     lateral=rng.integers(-2, 2, (n1, n1)).astype(np.int8)),
        snn.SNNLayer(rng.integers(-4, 8, (n2, n1)).astype(np.int8),
                     snn.LIFParams(thresh=n1, leak=1)),
    ]
    edges = (snn.RecurrentEdge(
        src=1, dst=0, weights=rng.integers(-2, 3, (n1, n2)).astype(np.int8)),)
    raster = snn.rate_encode(rng.random(n0), 6, seed=8)
    n_ticks = 12
    counts, totals = snn.oracle_run(layers, raster, edges=edges, n_ticks=n_ticks)
    job = snn.SNNJob(layers, raster, counts, int(totals.sum()),
                     edges=edges, n_ticks=n_ticks)
    groups = snn.layer_groups(layers, edges)
    assert max(g.width for g in groups) >= 2, "fan-in must tile into groups"
    assert sum(1 for g in groups if g.layer == 0) == 2, "two row stripes"
    descs = snn.segmentation_for(layers, "uniform", n_segments=3, edges=edges)
    cfg, ctl, meta = _run_vp(job, descs)
    assert cfg.snn_grouped
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta), counts)
    assert snn.total_spikes(st) == int(totals.sum())


# ---------------------------------------------------------------------------
# randomized sharding/backends property (mirrors test_snn_wide's sweep)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_recurrent_property(seed):
    """Random layer sizes / strategy / backend / quantum draw: cyclic VP
    runs are bit-identical to the cycle-aware oracle in every draw."""
    rng = np.random.default_rng(300 + seed)
    sizes = (int(rng.integers(12, 48)), int(rng.integers(16, 64)),
             int(rng.integers(6, 16)))
    job = snn.snn_recurrent_job(sizes, t_steps=int(rng.integers(4, 9)),
                                rate=0.5, seed=seed)
    strategy = rng.choice(["uniform", "load_oriented", "auto", "auto_traffic"])
    if strategy == "auto_traffic":
        _, traffic = snn.profile_traffic(job.layers, job.raster,
                                         edges=job.edges, n_ticks=job.n_ticks)
        descs, placement = snn.auto_segmentation_for(
            job.layers, n_segments=3, slots_per_seg=4, traffic=traffic,
            edges=job.edges)
    elif strategy == "auto":
        descs, placement = snn.auto_segmentation_for(
            job.layers, n_segments=3, slots_per_seg=4, edges=job.edges)
    else:
        descs = snn.segmentation_for(job.layers, str(strategy),
                                     n_segments=int(rng.integers(2, 5)),
                                     edges=job.edges)
        placement = None
    backend = str(rng.choice(["sequential", "vmap", "threads"]))
    quantum = int(rng.choice([16, 32, 64]))
    cfg, ctl, meta = _run_vp(job, descs, placement, backend=backend,
                             quantum=quantum)
    got = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(
        got, job.expected_counts,
        err_msg=f"sizes={sizes} strategy={strategy} backend={backend} q={quantum}")
    assert snn.total_spikes(ctl.result_states()) == job.expected_total
    ctl.close()


# ---------------------------------------------------------------------------
# traffic profiling of cyclic edges


def test_traffic_matrix_costs_cyclic_edges():
    rates, traffic = snn.profile_traffic(RJOB.layers, RJOB.raster,
                                         edges=RJOB.edges, n_ticks=RJOB.n_ticks)
    groups = snn.layer_groups(RJOB.layers, RJOB.edges)
    assert traffic.shape == (len(groups), len(groups))
    li = {g.layer: i for i, g in enumerate(groups)}  # single-stripe layers
    hid, out = li[len(RJOB.layers) - 2], li[len(RJOB.layers) - 1]
    assert traffic[hid, hid] > 0, "Elman lateral must appear on the diagonal"
    assert traffic[out, out] > 0, "WTA lateral must appear on the diagonal"
    assert traffic[out, hid] > 0, "feedback must appear on the backward block"
    assert traffic[hid, out] > 0, "the forward chain is still costed"
    # measured rates from a real run agree structurally
    descs = snn.segmentation_for(RJOB.layers, "uniform", n_segments=4,
                                 edges=RJOB.edges)
    cfg, ctl, meta = _run_vp(RJOB, descs)
    m_rates, m_traffic = snn.measure_traffic(ctl.result_states(), meta)
    assert ((m_traffic > 0) == (traffic > 0)).all()


def test_traffic_partition_ignores_self_traffic():
    """A group's lateral self-traffic (diagonal) is placement-invariant and
    must not skew the cut optimization."""
    from repro.core import segmentation as sg

    rng = np.random.default_rng(11)
    traffic = rng.random((4, 4)) * (rng.random((4, 4)) < 0.6)
    with_diag = traffic + np.diag([100.0, 50.0, 75.0, 25.0])
    a = sg.traffic_partition([1] * 4, [1.0] * 4, traffic, 2, 2)
    b = sg.traffic_partition([1] * 4, [1.0] * 4, with_diag, 2, 2)
    np.testing.assert_array_equal(a, b)
