"""Per-architecture smoke tests + decode-path consistency.

Every assigned arch instantiates its reduced config and runs one
forward/train step on CPU (shapes + finiteness); the cache paths are checked
by the teacher-forcing property: greedy prefill+decode logits must match the
full-sequence forward logits position by position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.model import build
from repro.models import transformer as TF


def make_batch(cfg, b, s, key):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(key, (b, 4, cfg.d_model), jnp.bfloat16)
        batch["mrope_pos"] = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, 1))
    if cfg.family == "encdec":
        batch["enc_feats"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    """prefill(x[:t]) + decode steps must reproduce forward(x) logits."""
    cfg = get_smoke_config(arch)
    model = build(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    b, s_total, s_prefill = 2, 32, 16  # chunk-aligned for ssm archs
    batch = make_batch(cfg, b, s_total, jax.random.PRNGKey(2))

    # full forward logits (teacher forcing)
    if cfg.family == "encdec":
        from repro.models import encdec as ED

        h = ED.encdec_loss_forward(cfg, params, batch, model.policy)
    else:
        h, _, _ = TF.forward(cfg, params, batch, model.policy, mode="train")
    full_logits = TF.lm_logits(cfg, params, h, model.policy)

    # prefill on the first s_prefill tokens, then decode the rest
    pre = {k: (v[:, :s_prefill] if k != "mrope_pos" else v[:, :, :s_prefill])
           if k in ("tokens", "mrope_pos") else v for k, v in batch.items()}
    cache, lg = model.prefill(params, pre)

    def pad_seq(x):
        if x.ndim >= 4 and x.shape[-3] == s_prefill:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, s_total - s_prefill)
            return jnp.pad(x, pad)
        return x

    cache = jax.tree.map(pad_seq, cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full_logits[:, s_prefill - 1], np.float32),
        rtol=0.2, atol=0.3,  # bf16 matmuls; dense vs flash accumulation
    )
    for t in range(s_prefill, s_total):
        db = {"tokens": batch["tokens"][:, t : t + 1]}
        if cfg.mrope:
            db["mrope_pos"] = batch["mrope_pos"][:, :, t : t + 1]
        lg, cache = model.decode_step(params, cache, db, t)
        got = np.asarray(lg[:, 0], np.float32)
        want = np.asarray(full_logits[:, t], np.float32)
        if cfg.moe is not None:
            # top-k routing is a discrete boundary: bf16 input jitter between
            # the cached-decode and teacher-forced paths can flip an expert
            # for a borderline token — tolerate a small mismatch fraction
            bad = np.abs(got - want) > 0.3 + 0.2 * np.abs(want)
            assert bad.mean() < 0.02, f"{arch} t={t}: {bad.mean():.3%} mismatched"
        else:
            np.testing.assert_allclose(
                got, want, rtol=0.2, atol=0.3,  # bf16 jitter on near-zero logits
                err_msg=f"{arch} decode step t={t}",
            )


def test_moe_dense_path_balances_and_routes():
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    model = build(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(3))
    loss, metrics = model.loss(params, batch)
    assert float(metrics["aux"]) > 0  # load-balance loss is active
