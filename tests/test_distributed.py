"""Multi-device tests (subprocesses with fake devices — the main pytest
process must keep seeing the single real CPU device):

- MoE expert-parallel shard_map path == dense reference path;
- shard_map simulation backend == vmap backend (paper core at scale);
- elastic restore: checkpoint saved on one dp degree restores onto another;
- loop-aware HLO cost analyzer counts collectives on a sharded module.
"""
import pytest


def test_moe_ep_matches_dense(subproc):
    subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs import get_smoke_config
from repro.models.moe import apply_moe, moe_specs
from repro.common import init_params
import dataclasses

cfg = get_smoke_config("kimi-k2-1t-a32b")
cfg = dataclasses.replace(cfg, d_model=64)
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
specs = moe_specs(cfg, tp=2)
params = init_params(jax.random.PRNGKey(0), specs)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)
y_dense, aux_d = apply_moe(cfg, params, x, __import__("repro.common", fromlist=["DTypePolicy"]).DTypePolicy(), mesh=None)
with compat.set_mesh(mesh):
    y_ep, aux_e = jax.jit(lambda p, x: apply_moe(cfg, p, x,
        __import__("repro.common", fromlist=["DTypePolicy"]).DTypePolicy(), mesh=mesh))(params, x)
# EP uses capacity-dropless path at this size: must match dense exactly-ish
np.testing.assert_allclose(np.asarray(y_ep, np.float32), np.asarray(y_dense, np.float32),
                           rtol=2e-2, atol=2e-2)
print("EP==dense OK", float(jnp.abs(y_ep - y_dense).max()))
""",
        n_devices=8,
    )


def test_shard_map_backend_matches_vmap(subproc):
    subproc(
        """
import jax, numpy as np
from repro import compat
from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import workloads as wl

layer = wl.Layer("t", "t", 8, 8, 4)
descs = sg.uniform(2, 2)
job = wl.cim_workload(layer, mgr_segments=[0, 1], cim_ids_per_mgr={0: (0, 1), 1: (2, 3)})
cfg, states, pending = sg.build(descs, programs=job["programs"], dram_words=job["dram"],
                                crossbars=job["crossbars"], scratch_init=job["scratch"],
                                channel_latency=2000)
mesh = compat.make_mesh((2,), ("segment",))
res = {}
for backend, kw in (("vmap", {}), ("shard_map", {"mesh": mesh})):
    ctl = Controller(cfg, states, pending, backend=backend, quantum=1000, **kw)
    ctl.run(max_rounds=200, check_every=1)
    st = ctl.result_states()
    res[backend] = (np.asarray(st["dram"]["data"][0][:4096]), np.asarray(st["time"]),
                    np.asarray(st["stats"]["instrs"]))
for a, b in zip(res["vmap"], res["shard_map"]):
    np.testing.assert_array_equal(a, b)
print("shard_map == vmap OK")
""",
        n_devices=2,
    )


def test_shard_map_backend_matches_vmap_snn(subproc):
    """SNN spike traffic over the shard_map backend == vmap, bit-exact."""
    subproc(
        """
import jax, numpy as np
from repro import compat, snn
from repro.core.controller import Controller

job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.6, seed=5)
descs = snn.segmentation_for(2, "uniform", n_segments=2)
cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
mesh = compat.make_mesh((2,), ("segment",))
res = {}
for backend, kw in (("vmap", {}), ("shard_map", {"mesh": mesh})):
    ctl = Controller(cfg, states, pending, backend=backend, quantum=32, **kw)
    ctl.run(max_rounds=100, check_every=1)
    st = ctl.result_states()
    res[backend] = (np.asarray(st["cims"]["spike_counts"]),
                    np.asarray(st["cims"]["v"]), np.asarray(st["cims"]["ticks"]))
for a, b in zip(res["vmap"], res["shard_map"]):
    np.testing.assert_array_equal(a, b)
np.testing.assert_array_equal(
    np.asarray(res["vmap"][0][meta["out_unit"][0], meta["out_unit"][1], :meta["n_out"]]),
    job.expected_counts)
print("shard_map SNN == vmap OK")
""",
        n_devices=2,
    )


def test_elastic_checkpoint_restore(subproc, tmp_path):
    """Save under dp=4 sharding, restore under dp=2 — logical arrays identical."""
    subproc(
        f"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.train import checkpoint as ckpt

mesh4 = compat.make_mesh((4, 2), ("data", "model"))
x = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
xs = jax.device_put(x, NamedSharding(mesh4, P("data", "model")))
ckpt.save(r"{tmp_path}", 5, {{"w": xs}})
mesh2 = compat.make_mesh((2, 4), ("data", "model"))
restored, at = ckpt.restore(r"{tmp_path}", {{"w": x}},
    shardings={{"w": NamedSharding(mesh2, P("data", "model"))}})
assert at == 5
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
assert restored["w"].sharding.mesh.shape["data"] == 2
print("elastic restore OK")
""",
        n_devices=8,
    )


def test_hlo_cost_counts_sharded_collectives(subproc):
    subproc(
        """
import jax, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.analysis.hlo_cost import analyze

mesh = compat.make_mesh((4, 2), ("data", "model"))
def f(w, x):
    def body(c, _):
        return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(body, x, None, length=7)
    return y.sum()
w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
with compat.set_mesh(mesh):
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                 NamedSharding(mesh, P("data", None))),
                out_shardings=NamedSharding(mesh, P())).lower(w, x).compile()
r = analyze(c.as_text())
expect = 7 * 2 * 256**3 / 8  # per-device
assert abs(r.flops - expect) / expect < 0.05, (r.flops, expect)
assert r.coll > 0, "collectives must be counted"
print("hlo_cost sharded OK", r.flops, r.coll)
""",
        n_devices=8,
    )
