"""SNN subsystem: LIF kernel vs oracle, AER delivery semantics, and
end-to-end VP-vs-oracle equivalence across segmentations and backends.

The headline property (mirroring the dense-VMM suite): simulating a
multi-layer LIF network on the VP — spikes crossing segment boundaries as
time-stamped AER events through the decoupled channel machinery — produces
*bit-identical* output spike counts to the pure-jnp oracle, under every
segmentation strategy and every controller backend.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import snn
from repro.core import channel as ch
from repro.core.controller import Controller
from repro.core.segmentation import build
from repro.kernels.lif_step import ops as lif_ops
from repro.kernels.lif_step import ref as lif_ref
from repro.vp import isa
from repro.vp.platform import IN_CAP


# ---------------------------------------------------------------------------
# kernel vs oracle


@pytest.mark.parametrize("shape,seed", [((1, 8, 8), 0), ((2, 100, 64), 1),
                                        ((3, 256, 256), 2), ((4, 130, 17), 3)])
def test_lif_kernel_matches_ref(shape, seed):
    u, r, c = shape
    rng = np.random.default_rng(seed)
    w = rng.integers(-8, 8, (u, r, c)).astype(np.int8)
    s = rng.integers(0, 4, (u, c)).astype(np.int32)
    v = rng.integers(0, 60, (u, r)).astype(np.int32)
    rf = rng.integers(0, 3, (u, r)).astype(np.int32)
    th = rng.integers(1, 80, (u,)).astype(np.int32)
    lk = rng.integers(0, 6, (u,)).astype(np.int32)
    rp = rng.integers(0, 4, (u,)).astype(np.int32)
    args = tuple(jnp.asarray(x) for x in (w, s, v, rf, th, lk, rp))
    got = lif_ops.lif_step_units(*args)
    want = lif_ref.lif_step_units(*args)
    for g, e, name in zip(got, want, ("v", "refrac", "fired")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e), err_msg=name)


def test_lif_kernel_exact_at_saturated_fanin():
    """Huge per-axon counts saturate identically in kernel and oracle —
    the fp32 MXU contraction must never leave the exact-integer range."""
    rng = np.random.default_rng(9)
    w = rng.integers(-128, 128, (2, 256, 256)).astype(np.int8)
    s = rng.integers(0, 100_000, (2, 256)).astype(np.int32)
    v = np.zeros((2, 256), np.int32)
    rf = np.zeros((2, 256), np.int32)
    one = np.ones((2,), np.int32)
    args = tuple(jnp.asarray(x) for x in (w, s, v, rf, one * 50, one, one * 0))
    got = lif_ops.lif_step_units(*args)
    want = lif_ref.lif_step_units(*args)
    for g, e in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def test_lif_semantics_refractory_and_leak():
    """Hand-checked single neuron: charge, fire, refract, recover."""
    w = jnp.asarray([[10]], jnp.int8)
    p = snn.LIFParams(thresh=25, leak=2, refrac_period=2)
    st = snn.pool_state(1)
    fired_at = []
    for tick in range(12):
        st, fired = snn.lif_step(st, w, jnp.asarray([1], jnp.int32), p)
        if int(fired[0]):
            fired_at.append(tick)
    # +8 net per tick: v = 8, 16, 24, 32 >= 25 -> fires tick 3; two silent
    # refractory ticks (input ignored, leak floors v at 0), then recharges
    # 8/tick from 0 -> fires again at tick 9
    assert fired_at == [3, 9]


# ---------------------------------------------------------------------------
# AER delivery: tick bucketing, accumulation, MMIO mode register


def _one_unit_vp(raster, **kw):
    layers = [snn.SNNLayer(np.eye(4, dtype=np.int8) * 10,
                           snn.LIFParams(thresh=10, leak=0))]
    descs = snn.segmentation_for(1, "uniform", n_segments=2)
    return snn.build_snn(layers, descs, raster, **kw)


def test_aer_spikes_integrate_at_their_tick():
    """Identity net, thresh == one synapse hit: the unit's output counts
    reproduce the raster exactly — every event lands in its own tick."""
    raster = np.zeros((5, 4), np.int32)
    raster[0, 0] = raster[2, 1] = raster[4, 3] = 1
    cfg, states, pending, meta = _one_unit_vp(raster)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.run(max_rounds=100, check_every=1)
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                  raster.sum(0))
    # every tick that integrated input fired exactly the addressed neuron
    assert snn.total_spikes(st) == int(raster.sum())


def test_same_tick_spikes_accumulate():
    """Two spikes on one axon in one tick sum (scatter-add, order-free)."""
    raster = np.zeros((2, 4), np.int32)
    raster[0, 2] = 2  # weighted event: counts as two simultaneous spikes
    layers = [snn.SNNLayer(np.eye(4, dtype=np.int8) * 10,
                           snn.LIFParams(thresh=20, leak=0))]
    descs = snn.segmentation_for(1, "uniform", n_segments=2)
    cfg, states, pending, meta = snn.build_snn(layers, descs, raster)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.run(max_rounds=100, check_every=1)
    got = snn.output_spike_counts(ctl.result_states(), meta)
    np.testing.assert_array_equal(got, [0, 0, 1, 0])  # 2×10 >= 20 fires once


def test_cross_segment_delivery_is_one_tick_delayed():
    """Layer on segment A feeding a layer on segment B: the downstream
    tick count trails upstream by exactly the one-hop axonal delay."""
    job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.6, seed=5)
    descs = snn.segmentation_for(2, "uniform", n_segments=2)  # 1 unit/segment
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.run(max_rounds=100, check_every=1)
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                  job.expected_counts)
    (s0, k0), (s1, k1) = meta["unit_of_layer"]
    assert s0 != s1, "placement must cross a segment boundary"


def test_mode_register_mmio():
    """CIM_REG_MODE write via the channel flips a unit into spike mode."""
    from repro.core.segmentation import SegmentDesc
    from repro.vp import platform as pf

    descs = [SegmentDesc(cpu=True, dram=True, n_cims=1, cim_mgr=0)]
    cfg, states, pending, = build(descs, channel_latency=1000)
    val = isa.pack_mode(isa.CIM_MODE_SPIKE, thresh=40, leak=3, refrac=2)
    pending = dict(pending)
    for f, v in (("kind", ch.MSG_W_CIM), ("addr", (0 << 16) | isa.CIM_REG_MODE),
                 ("data", val), ("t_avail", 0)):
        pending[f] = pending[f].at[0, 0].set(v)
    pending["valid"] = pending["valid"].at[0, 0].set(True)
    pending["count"] = pending["count"].at[0].set(1)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    ctl.round()
    cims = ctl.result_states()["cims"]
    assert int(cims["mode"][0, 0]) == isa.CIM_MODE_SPIKE
    assert int(cims["thresh"][0, 0]) == 40
    assert int(cims["leak"][0, 0]) == 3
    assert int(cims["refrac_period"][0, 0]) == 2


def test_raster_overflow_rejected():
    raster = np.ones((IN_CAP, 4), np.int32)
    with pytest.raises(AssertionError, match="overflow"):
        _one_unit_vp(raster)


# ---------------------------------------------------------------------------
# end-to-end: VP == oracle, across segmentations and backends


JOB = snn.snn_inference_job((64, 48, 32, 10), t_steps=12, rate=0.5, seed=1)


@pytest.mark.parametrize("strategy", ["uniform", "load_oriented"])
def test_three_layer_net_matches_oracle(strategy):
    """Acceptance: 3-layer LIF net on a 4-segment VP == pure-jnp oracle."""
    descs = snn.segmentation_for(len(JOB.layers), strategy, n_segments=4)
    assert len(descs) == 4
    cfg, states, pending, meta = snn.build_snn(JOB.layers, descs, JOB.raster)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    ctl.run(max_rounds=300, check_every=1)
    st = ctl.result_states()
    np.testing.assert_array_equal(snn.output_spike_counts(st, meta),
                                  JOB.expected_counts)
    assert snn.total_spikes(st) == JOB.expected_total
    assert ctl.stats()["txn_histogram"][ch.MSG_SPIKE] > 0


def test_backends_bit_identical_spike_counts():
    """sequential vs vmap vs threads: identical per-neuron spike counts
    everywhere (shard_map is covered in test_distributed.py — it needs a
    multi-device subprocess)."""
    descs = snn.segmentation_for(len(JOB.layers), "load_oriented", n_segments=4)
    cfg, states, pending, meta = snn.build_snn(JOB.layers, descs, JOB.raster)
    res = {}
    for backend in ("sequential", "vmap", "threads"):
        ctl = Controller(cfg, states, pending, backend=backend, quantum=32)
        ctl.run(max_rounds=300, check_every=1)
        st = ctl.result_states()
        res[backend] = (np.asarray(st["cims"]["spike_counts"]),
                        np.asarray(st["cims"]["v"]),
                        np.asarray(st["cims"]["ticks"]))
    for backend in ("vmap", "threads"):
        for a, b in zip(res["sequential"], res[backend]):
            np.testing.assert_array_equal(a, b)


def test_kernel_path_matches_ref_path():
    """use_kernel=True routes LIF ticks through the Pallas kernel."""
    job = snn.snn_inference_job((32, 24, 10), t_steps=8, rate=0.5, seed=3)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    outs = []
    for use_kernel in (False, True):
        cfg, states, pending, meta = snn.build_snn(
            job.layers, descs, job.raster, use_kernel=use_kernel)
        ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
        ctl.run(max_rounds=300, check_every=1)
        outs.append(snn.output_spike_counts(ctl.result_states(), meta))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], job.expected_counts)


def test_auto_placement_matches_oracle_and_balances():
    """auto strategy: cost-balanced layer->unit map still runs the chain."""
    job = snn.snn_inference_job((16, 128, 8, 8), t_steps=6, rate=0.6, seed=7)
    descs, placement = snn.auto_segmentation_for(job.layers, n_segments=3)
    assert sorted(placement) == list(range(len(job.layers)))
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster,
                                               placement=placement)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    ctl.run(max_rounds=300, check_every=1)
    np.testing.assert_array_equal(snn.output_spike_counts(ctl.result_states(), meta),
                                  job.expected_counts)
    # the heavy 16x128 layer must not share a segment with another layer
    heavy_seg = meta["unit_of_layer"][1][0]
    others = [s for i, (s, _) in enumerate(meta["unit_of_layer"]) if i != 1]
    assert heavy_seg not in others


def test_spikes_to_never_ticking_unit_are_dropped():
    """AER events addressed to an unwired slot must not wedge termination."""
    raster = np.zeros((2, 4), np.int32)
    raster[0, 0] = 1
    cfg, states, pending, meta = _one_unit_vp(raster)
    # misaddress one extra event at slot 1 (present in state, never ticks)
    pending = dict(pending)
    for f, v in (("kind", ch.MSG_SPIKE), ("addr", (1 << 16) | 0),
                 ("data", 1), ("t_avail", 10_000)):
        pending[f] = pending[f].at[0, 100].set(v)
    pending["valid"] = pending["valid"].at[0, 100].set(True)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=16)
    rounds, _ = ctl.run(max_rounds=60, check_every=1)
    assert ctl.done(), "stray spike must be dropped, not pend forever"
    np.testing.assert_array_equal(snn.output_spike_counts(ctl.result_states(), meta),
                                  raster.sum(0))


def test_more_than_two_layers_per_segment():
    """5-layer chain on 2 segments: slot state must size to the densest
    segment (3 slots) instead of silently clobbering slot 1."""
    job = snn.snn_inference_job((16, 12, 12, 12, 12, 8), t_steps=6, rate=0.6, seed=11)
    descs = snn.segmentation_for(len(job.layers), "uniform", n_segments=2)
    assert max(d.n_cims for d in descs) == 3
    cfg, states, pending, meta = snn.build_snn(job.layers, descs, job.raster)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
    ctl.run(max_rounds=300, check_every=1)
    np.testing.assert_array_equal(snn.output_spike_counts(ctl.result_states(), meta),
                                  job.expected_counts)


def test_quantum_invariance():
    """Spike counts are invariant to the quantum (decoupling property)."""
    descs = snn.segmentation_for(len(JOB.layers), "uniform", n_segments=4)
    cfg, states, pending, meta = snn.build_snn(JOB.layers, descs, JOB.raster)
    ref = None
    for quantum in (16, 64):
        ctl = Controller(cfg, states, pending, backend="vmap", quantum=quantum)
        ctl.run(max_rounds=300, check_every=1)
        got = snn.output_spike_counts(ctl.result_states(), meta)
        if ref is None:
            ref = got
        np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(ref, JOB.expected_counts)
