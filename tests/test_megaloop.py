"""The device-resident megaloop is pure mechanism: fusing exec+sync rounds
into one jitted ``lax.while_loop`` with on-device termination must be
bit-identical to per-round dispatch — same final states, same pending
boxes, same round counts, same overflow errors — for every backend ×
quantum × check cadence × dispatch granularity (ISSUE 3 / docs/architecture.md
"The device-resident megaloop").

Deterministic parametrized coverage always runs; a randomized hypothesis
property sweep rides on top when the 'test' extra is installed.
"""
import jax
import numpy as np
import pytest

from repro.core import segmentation as sg
from repro.core.controller import Controller
from repro.vp import platform as pf
from repro.vp import workloads as wl

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

LAYER = wl.Layer("mega", "t", 8, 8, 4)


def build_cim(channel_latency=2000):
    descs = sg.uniform(2, 2)
    job = wl.cim_workload(LAYER, mgr_segments=[0, 1],
                          cim_ids_per_mgr={0: (0, 1), 1: (2, 3)})
    return sg.build(descs, programs=job["programs"], dram_words=job["dram"],
                    crossbars=job["crossbars"], scratch_init=job["scratch"],
                    channel_latency=channel_latency)


def build_snn():
    from repro import snn

    job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.6, seed=5)
    descs = snn.segmentation_for(2, "uniform", n_segments=2)
    cfg, states, pending, _meta = snn.build_snn(job.layers, descs, job.raster)
    return cfg, states, pending


def final(sim, backend, quantum, check_every, max_rounds=300, **kw):
    cfg, states, pending = sim
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    rounds, _ = ctl.run(max_rounds=max_rounds, check_every=check_every, **kw)
    return rounds, ctl.result_states(), ctl._pending_stacked()


def assert_identical(a, b):
    ra, sta, pea = a
    rb, stb, peb = b
    assert ra == rb, f"round counts differ: {ra} vs {rb}"
    for x, y in zip(jax.tree.leaves(sta), jax.tree.leaves(stb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(pea), jax.tree.leaves(peb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def cim_sim():
    return build_cim()


@pytest.fixture(scope="module")
def snn_sim():
    return build_snn()


@pytest.mark.parametrize("quantum,check_every,k", [
    (1000, 1, 1), (1000, 2, 3), (1000, 3, 64), (500, 4, 2), (2000, 1, 256),
])
def test_megaloop_bit_identical_cim(cim_sim, quantum, check_every, k):
    ref = final(cim_sim, "vmap", quantum, check_every, fused=False)
    got = final(cim_sim, "vmap", quantum, check_every, fused=True,
                rounds_per_dispatch=k)
    assert_identical(got, ref)


@pytest.mark.parametrize("backend", ["sequential", "threads"])
def test_megaloop_matches_host_loop_backends(cim_sim, backend):
    """The megaloop agrees with the honest host-looped baselines too."""
    ref = final(cim_sim, backend, 1000, 2)
    got = final(cim_sim, "vmap", 1000, 2, fused=True, rounds_per_dispatch=32)
    assert_identical(got, ref)


@pytest.mark.parametrize("check_every,k", [(1, 1), (2, 7), (3, 64)])
def test_megaloop_bit_identical_snn(snn_sim, check_every, k):
    ref = final(snn_sim, "vmap", 32, check_every, fused=False)
    got = final(snn_sim, "vmap", 32, check_every, fused=True,
                rounds_per_dispatch=k)
    assert_identical(got, ref)


def test_megaloop_early_termination(cim_sim):
    """A workload that finishes long before max_rounds must stop at the same
    check round fused and unfused, well short of the dispatch budget."""
    r_ref, _, _ = final(cim_sim, "vmap", 1000, 2, max_rounds=500, fused=False)
    r_got, _, _ = final(cim_sim, "vmap", 1000, 2, max_rounds=500, fused=True,
                        rounds_per_dispatch=500)
    assert r_got == r_ref < 500


def test_capacity_invariance_snn():
    """Right-sized channel caps are bit-identical to the generous defaults
    (the sticky watermarks police overflow, so small caps are safe), fused
    and unfused."""
    from repro import snn

    job = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.6, seed=5)
    descs = snn.segmentation_for(2, "uniform", n_segments=2)
    runs = {}
    for name, caps in (("default", {}), ("small", dict(in_cap=256, out_cap=128))):
        cfg, states, pending, _ = snn.build_snn(job.layers, descs, job.raster, **caps)
        for fused in (False, True):
            ctl = Controller(cfg, states, pending, backend="vmap", quantum=32)
            rounds, _ = ctl.run(max_rounds=300, check_every=2, fused=fused)
            runs[(name, fused)] = (rounds, ctl.result_states())
    ref_rounds, ref_st = runs[("default", False)]
    for key, (rounds, st) in runs.items():
        assert rounds == ref_rounds, key
        for x, y in zip(jax.tree.leaves(ref_st), jax.tree.leaves(st)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_megaloop_inbox_overflow_same_error(monkeypatch):
    """The on-device sticky watermark still surfaces as the same loud
    RuntimeError: shrink IN_CAP so the workload's MMIO burst overflows the
    pending box, and require fused and per-round execution to raise the
    identical message (same stop round -> same watermark list)."""
    monkeypatch.setattr(pf, "IN_CAP", 4)
    sim = build_cim(channel_latency=1999)  # unique fn-cache key for the patch

    msgs = {}
    for name, kw in (("per_round", dict(fused=False)),
                     ("mega", dict(fused=True, rounds_per_dispatch=64))):
        with pytest.raises(RuntimeError, match="overflow") as ei:
            final(sim, "vmap", 1999, 2, **kw)
        msgs[name] = str(ei.value)
    assert msgs["mega"] == msgs["per_round"]
    assert "pending inbox overflow" in msgs["mega"]


@pytest.mark.parametrize("backend", ["sequential", "threads", "vmap"])
def test_controller_usable_after_watermark_error(backend):
    """A watermark RuntimeError must not poison the process: after one
    controller aborts on overflow, a fresh controller on a fresh workload
    runs to completion (the compiled-function cache, donated buffers, and
    backend pools all survive the error path), and the failed controller's
    results stay readable."""
    from repro import snn

    job = snn.snn_inference_job((8, 200, 8), t_steps=3, rate=0.9, seed=4)
    descs = snn.segmentation_for(snn.n_units_for(job.layers), "uniform",
                                 n_segments=2)
    cfg, states, pending, _ = snn.build_snn(job.layers, descs, job.raster,
                                            out_cap=24)
    bad = Controller(cfg, states, pending, backend=backend, quantum=32)
    with pytest.raises(RuntimeError, match="outbox overflow"):
        bad.run(max_rounds=300, check_every=2)
    # the erroring controller's state stays readable after the abort
    assert int(np.asarray(bad.result_states()["stats"]["outbox_peak"]).max()) > 24
    assert bad.stats() is not None

    job2 = snn.snn_inference_job((16, 12, 8), t_steps=6, rate=0.6, seed=5)
    descs2 = snn.segmentation_for(2, "uniform", n_segments=2)
    cfg2, states2, pending2, meta2 = snn.build_snn(job2.layers, descs2,
                                                   job2.raster)
    good = Controller(cfg2, states2, pending2, backend=backend, quantum=32)
    rounds, _ = good.run(max_rounds=300, check_every=2)
    counts = np.asarray(snn.output_spike_counts(good.result_states(), meta2))
    np.testing.assert_array_equal(counts, job2.expected_counts)


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        quantum=st.sampled_from([500, 1000, 2000]),
        check_every=st.integers(min_value=1, max_value=5),
        k=st.sampled_from([1, 2, 3, 7, 64, 500]),
        backend=st.sampled_from(["vmap", "sequential"]),
    )
    def test_megaloop_property(quantum, check_every, k, backend):
        """Random (quantum, cadence, dispatch granularity, reference backend):
        megaloop execution is always bit-identical to per-round execution."""
        sim = build_cim()
        ref = final(sim, backend, quantum, check_every, fused=False)
        got = final(sim, "vmap", quantum, check_every, fused=True,
                    rounds_per_dispatch=k)
        assert_identical(got, ref)
