"""Documentation smoke checks: the docs' commands, links, and path
references must match the repository (scripts/check_docs.py), and the
user-facing docs the issue tracker promises must actually exist."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_doc_set_exists():
    for doc in ("README.md", "docs/architecture.md", "docs/vp.md",
                "docs/snn.md", "benchmarks/README.md"):
        assert (REPO / doc).exists(), f"missing {doc}"


def test_no_orphaned_doc_pages():
    """Every checked doc page must be reachable from README.md
    (check_docs.py rule 5 — exercised directly so a failure names the
    orphans without rerunning the whole checker)."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "scripts" / "check_docs.py")
    check_docs = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(check_docs)
    problems = []
    check_docs.check_reachability(problems)
    assert not problems, f"orphaned doc pages (link them from README): {problems}"


def test_docs_commands_and_links_resolve():
    out = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_docs.py")],
        cwd=REPO, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, f"docs drifted:\n{out.stdout}{out.stderr}"


def test_readme_states_tier1_line():
    # the quickstart must carry the ROADMAP's tier-1 verify command
    readme = (REPO / "README.md").read_text()
    assert "python -m pytest -x -q" in readme
    assert "PYTHONPATH=src" in readme
