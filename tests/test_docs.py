"""Documentation smoke checks: the docs' commands, links, and path
references must match the repository (scripts/check_docs.py), and the
user-facing docs the issue tracker promises must actually exist."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_doc_set_exists():
    for doc in ("README.md", "docs/architecture.md", "docs/snn.md",
                "benchmarks/README.md"):
        assert (REPO / doc).exists(), f"missing {doc}"


def test_docs_commands_and_links_resolve():
    out = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_docs.py")],
        cwd=REPO, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, f"docs drifted:\n{out.stdout}{out.stderr}"


def test_readme_states_tier1_line():
    # the quickstart must carry the ROADMAP's tier-1 verify command
    readme = (REPO / "README.md").read_text()
    assert "python -m pytest -x -q" in readme
    assert "PYTHONPATH=src" in readme
