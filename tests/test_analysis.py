"""Loop-aware HLO cost analyzer: exactness on known-FLOP programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze
from repro.analysis.roofline import Roofline, active_params, model_flops
from repro.configs import SHAPES, get_config


def test_scan_trip_multiplication():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(s, s).compile()
    r = analyze(c.as_text())
    expect = 13 * 2 * 128**3
    assert abs(r.flops - expect) / expect < 0.02
    assert any(t == 13 for _, t in r.trip_counts)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(s, s).compile()
    r = analyze(c.as_text())
    expect = 15 * 2 * 64**3
    assert abs(r.flops - expect) / expect < 0.05, r.flops


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, bytes_accessed=819e9 * 2, coll_bytes=0)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert r.bottleneck == "memory"


def test_active_params_moe_vs_dense():
    kimi = get_config("kimi-k2-1t-a32b")
    act = active_params(kimi)
    assert 2.5e10 < act < 5e10  # ~32B active of ~1T total
    dense = get_config("qwen3-1.7b")
    act_d = active_params(dense)
    assert 1.5e9 < act_d < 2.3e9


def test_model_flops_kinds():
    cfg = get_config("qwen3-1.7b")
    tr = model_flops(cfg, SHAPES["train_4k"], "train")
    pf = model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    de = model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert tr > pf > de > 0
