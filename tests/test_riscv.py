"""ISS unit + property tests: real RV32IM encodings, decode, execution
semantics vs a python oracle over randomized arithmetic programs."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need the 'test' extra (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.vp import isa, riscv
from repro.vp.assembler import assemble


def run_program(asm: str, max_steps: int = 2000):
    words = assemble(asm)
    cpu = riscv.cpu_state()
    cpu["present"] = jnp.asarray(True)
    prog = jnp.zeros((512,), jnp.uint32).at[: len(words)].set(jnp.asarray(words))
    for _ in range(max_steps):
        instr = prog[(cpu["pc"] >> 2) % 512]
        cpu, mem = riscv.execute(cpu, instr)
        assert not bool(mem["is_load"]) and not bool(mem["is_store"]), "arith only"
        if bool(cpu["halted"]):
            break
    return np.asarray(cpu["regs"])


def test_encodings_known_words():
    # cross-checked against riscv-tests reference encodings
    assert assemble("addi t0, zero, 5")[0] == 0x00500293
    assert assemble("add t1, t0, t0")[0] == 0x00528333
    assert assemble("mul t1, t0, t0")[0] == 0x02528333
    assert assemble("lw t0, 8(sp)")[0] == 0x00812283
    assert assemble("sw t0, 12(sp)")[0] == 0x00512623


def test_branch_loop_sum():
    regs = run_program(
        """
        li t0, 0
        li t1, 0
        li t2, 10
    loop:
        add t0, t0, t1
        addi t1, t1, 1
        blt t1, t2, loop
        halt
        """
    )
    assert regs[isa.reg("t0")] == sum(range(10))


def test_li_large_immediate():
    regs = run_program("li t3, 0x40002000\nhalt")
    assert regs[isa.reg("t3")] == 0x40002000
    regs = run_program("li t3, -12345678\nhalt")
    assert regs[isa.reg("t3")] == -12345678


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["add", "sub", "mul", "addi"]),
    st.integers(5, 9),  # rd in t0..s1 range
    st.integers(5, 9),
    st.integers(5, 9),
    st.integers(-2048, 2047),
), min_size=1, max_size=25))
def test_random_arith_vs_oracle(ops):
    """Random straight-line arithmetic: ISS == python int32 oracle."""
    lines, oracle = [], [0] * 32
    names = {5: "t0", 6: "t1", 7: "t2", 8: "s0", 9: "s1"}
    for i in range(5, 10):
        lines.append(f"addi {names[i]}, zero, {i * 7}")
        oracle[i] = i * 7
    for op, rd, rs1, rs2, imm in ops:
        if op == "addi":
            lines.append(f"addi {names[rd]}, {names[rs1]}, {imm}")
            oracle[rd] = _i32(oracle[rs1] + imm)
        else:
            lines.append(f"{op} {names[rd]}, {names[rs1]}, {names[rs2]}")
            a, b = oracle[rs1], oracle[rs2]
            val = a + b if op == "add" else a - b if op == "sub" else a * b
            oracle[rd] = _i32(val)
    lines.append("halt")
    regs = run_program("\n".join(lines))
    for r in range(5, 10):
        assert regs[r] == oracle[r], (r, lines)


def _i32(v):
    v &= 0xFFFFFFFF
    return v - (1 << 32) if v >= (1 << 31) else v
