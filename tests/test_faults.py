"""Fault-injection conformance (repro.faults, docs/faults.md).

Three contracts, each a sweep cell:

  1. **Off means off** — ``faults=None`` and every-rate-zero configs build
     states with no fault arrays and produce bit-identical results to a
     pre-fault build (the ``obs=None`` compile-out pattern).
  2. **Seeded determinism** — a fixed seed yields bit-identical fault
     sites and results across every backend (sequential / threads / vmap,
     per-round and megaloop; shard_map in a multi-device subprocess),
     every quantum, and every segmentation (compared through the
     placement-independent readback, since raw states differ in layout).
  3. **Graceful degradation** — ``on_overflow="drop"`` completes where the
     default policy aborts, loses the *same* spikes fused vs per-round and
     across backends, and counts the loss (``lost_total`` /
     ``outbox_lost`` / ``faults.*`` metrics).
"""
import jax
import numpy as np
import pytest

from repro import faults as flt
from repro import snn
from repro.core.controller import Controller

JOB = snn.snn_inference_job((32, 24, 10), t_steps=8, rate=0.5, seed=2)

FAULT_CONFIGS = {
    "transport": flt.FaultConfig(seed=7, p_spike_drop=0.25, p_spike_dup=0.1),
    "crossbar": flt.FaultConfig(seed=7, p_stuck0=0.1, p_stuck1=0.05,
                                p_bitflip=0.05, p_row_fail=0.02,
                                p_col_fail=0.02),
    "neuron": flt.FaultConfig(seed=7, p_dead=0.2, p_thresh_drift=0.3),
    "all": flt.FaultConfig(seed=7, p_spike_drop=0.2, p_stuck0=0.1,
                           p_dead=0.1),
}

MODES = (
    ("sequential", "sequential", None),
    ("threads", "threads", None),
    ("vmap/per-round", "vmap", False),
    ("vmap/megaloop", "vmap", True),
)


def build(fc, n_segments=2, strategy="uniform", **kw):
    descs = snn.segmentation_for(snn.n_units_for(JOB.layers), strategy,
                                 **({"n_segments": n_segments}
                                    if strategy == "uniform" else {}))
    return snn.build_snn(JOB.layers, descs, JOB.raster, edges=JOB.edges,
                         n_ticks=JOB.n_ticks, faults=fc, **kw)


def run(sim, backend="vmap", fused=True, quantum=32, max_rounds=400):
    cfg, states, pending, meta = sim
    ctl = Controller(cfg, states, pending, backend=backend, quantum=quantum)
    rounds, _ = ctl.run(max_rounds=max_rounds, check_every=2, fused=fused)
    return rounds, ctl, meta


def readback(ctl, meta):
    """Placement-independent result signature: output spike counts + the
    all-layer spike total (raw states differ in layout across
    segmentations, so cross-segmentation cells compare through this)."""
    st = ctl.result_states()
    return (np.asarray(snn.output_spike_counts(st, meta)),
            int(snn.total_spikes(st)))


# ---------------------------------------------------------------------------
# 1. faults=None / all-rates-zero compile out bit-identically


def test_faults_none_is_bit_identical_to_baseline():
    base = build(None)
    for label, backend, fused in MODES:
        r0, c0, m0 = run(base, backend, fused)
        np.testing.assert_array_equal(readback(c0, m0)[0],
                                      JOB.expected_counts, err_msg=label)


def test_faults_none_adds_no_state():
    cfg, states, _, _ = build(None)
    assert cfg.faults is None
    assert "faults" not in states
    for k in ("f_and", "f_xor", "f_dead", "f_dth", "f_uid"):
        assert k not in states["cims"], k
    for k in ("spikes_dropped", "spikes_duped", "outbox_lost"):
        assert k not in states["stats"], k


def test_zero_rate_config_compiles_out_nothing_but_matches():
    """An all-zero FaultConfig keeps the arrays out too (every has_* gate
    is False) and reproduces the baseline bit-for-bit."""
    fc = flt.FaultConfig(seed=99)
    assert not (fc.has_xbar_faults or fc.has_neuron_faults
                or fc.has_transport_faults)
    cfg, states, pending, meta = build(fc)
    for k in ("f_and", "f_xor", "f_dead", "f_dth", "f_uid"):
        assert k not in states["cims"], k
    r, ctl, _ = run((cfg, states, pending, meta))
    rb, cb, mb = run(build(None))
    assert r == rb
    for x, y in zip(jax.tree.leaves(ctl.result_states()),
                    jax.tree.leaves(cb.result_states())):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# 2. seeded determinism across backends x dispatch x quantum x segmentation


@pytest.mark.parametrize("family", sorted(FAULT_CONFIGS))
def test_fault_sites_identical_across_backends(family):
    fc = FAULT_CONFIGS[family]
    sim = build(fc)
    ref = None
    for label, backend, fused in MODES:
        rounds, ctl, meta = run(sim, backend, fused)
        got = (rounds, ctl.result_states(), ctl._pending_stacked())
        ctl.close()
        if ref is None:
            ref = got
            continue
        assert got[0] == ref[0], f"{family}/{label}: round counts"
        for x, y in zip(jax.tree.leaves(got[1:]), jax.tree.leaves(ref[1:])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{family}/{label}")


@pytest.mark.parametrize("family", ["transport", "all"])
def test_fault_results_quantum_invariant(family):
    fc = FAULT_CONFIGS[family]
    outs = [readback(*run(build(fc), quantum=q)[1:]) for q in (16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_array_equal(o[0], outs[0][0])
        assert o[1] == outs[0][1]


@pytest.mark.parametrize("family", sorted(FAULT_CONFIGS))
def test_fault_results_segmentation_invariant(family):
    """The fault PRNG keys on logical unit identity and tick coordinates,
    never placement: every segmentation sees the same faulted network."""
    fc = FAULT_CONFIGS[family]
    outs = [readback(*run(build(fc, n_segments=n, strategy=s))[1:])
            for n, s in ((2, "uniform"), (3, "uniform"),
                         (None, "load_oriented"))]
    for o in outs[1:]:
        np.testing.assert_array_equal(o[0], outs[0][0])
        assert o[1] == outs[0][1]


def test_different_seeds_differ():
    """Sanity: the seed actually matters (a constant-fault bug would pass
    every determinism cell above)."""
    a = readback(*run(build(flt.FaultConfig(seed=1, p_spike_drop=0.3)))[1:])
    b = readback(*run(build(flt.FaultConfig(seed=2, p_spike_drop=0.3)))[1:])
    assert a[1] != b[1] or (a[0] != b[0]).any()


def test_fault_counters_and_kernel_parity():
    """Transport runs count their injections; the Pallas kernel path
    (use_kernel=True) agrees with the jnp ref bit-for-bit under crossbar +
    neuron faults."""
    fc = FAULT_CONFIGS["transport"]
    _, ctl, meta = run(build(fc))
    m = ctl.metrics()
    assert int(m["faults.spikes_dropped"].sum()) > 0
    assert int(m["faults.spikes_duped"].sum()) > 0

    fcx = flt.FaultConfig(seed=7, p_stuck0=0.15, p_dead=0.1)
    ref = readback(*run(build(fcx))[1:])
    ker = readback(*run(build(fcx, use_kernel=True))[1:])
    np.testing.assert_array_equal(ref[0], ker[0])
    assert ref[1] == ker[1]


def test_faults_shard_map_conformance(subproc):
    """The fourth backend: a faulted shard_map run matches vmap
    bit-for-bit (transport + structural families)."""
    subproc(
        """
import jax, numpy as np
from repro import compat, faults as flt, snn
from repro.core.controller import Controller

mesh = compat.make_mesh((2,), ("segment",))
job = snn.snn_inference_job((32, 24, 10), t_steps=8, rate=0.5, seed=2)
descs = snn.segmentation_for(snn.n_units_for(job.layers), "uniform",
                             n_segments=2)
for fc in (flt.FaultConfig(seed=7, p_spike_drop=0.25, p_spike_dup=0.1),
           flt.FaultConfig(seed=7, p_stuck0=0.1, p_dead=0.2)):
    cfg, states, pending, meta = snn.build_snn(
        job.layers, descs, job.raster, faults=fc)
    res = {}
    for backend, kw in (("vmap", {}), ("shard_map", {"mesh": mesh})):
        ctl = Controller(cfg, states, pending, backend=backend, quantum=32,
                         **kw)
        rounds, _ = ctl.run(max_rounds=400, check_every=2)
        res[backend] = (rounds, ctl.result_states(), ctl._pending_stacked())
    assert res["vmap"][0] == res["shard_map"][0]
    for x, y in zip(jax.tree.leaves(res["vmap"][1:]),
                    jax.tree.leaves(res["shard_map"][1:])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("faulted shard_map conformance OK")
""",
        n_devices=2,
    )


# ---------------------------------------------------------------------------
# 3. graceful degradation: on_overflow="drop"


BURST = snn.snn_inference_job((8, 200, 8), t_steps=3, rate=0.9, seed=4)


def _burst(fc, **caps):
    descs = snn.segmentation_for(snn.n_units_for(BURST.layers), "uniform",
                                 n_segments=2)
    return snn.build_snn(BURST.layers, descs, BURST.raster, faults=fc,
                         **caps)


def _run_burst(fc, backend, fused, **caps):
    cfg, states, pending, meta = _burst(fc, **caps)
    ctl = Controller(cfg, states, pending, backend=backend, quantum=32)
    rounds, _ = ctl.run(max_rounds=400, check_every=2, fused=fused)
    st = ctl.result_states()
    return {
        "rounds": rounds,
        "counts": np.asarray(snn.output_spike_counts(st, meta)),
        "inbox_lost": int(np.asarray(
            ctl._pending_stacked()["lost_total"]).sum()),
        "outbox_lost": int(np.asarray(
            st["stats"].get("outbox_lost", 0)).sum()),
    }


DROP = flt.FaultConfig(on_overflow="drop")


@pytest.mark.parametrize("caps,lost_key", [
    (dict(out_cap=24), "outbox_lost"),
    (dict(in_cap=48, out_cap=640), "inbox_lost"),
])
def test_drop_policy_completes_and_counts_loss(caps, lost_key):
    """Where the default policy raises, drop completes — and every backend
    and dispatch mode loses the identical spikes and counts them."""
    with pytest.raises(RuntimeError, match="overflow"):
        _run_burst(None, "vmap", True, **caps)
    ref = _run_burst(DROP, "vmap", True, **caps)
    assert ref[lost_key] > 0
    for backend, fused in (("vmap", False), ("sequential", False),
                           ("threads", False)):
        got = _run_burst(DROP, backend, fused, **caps)
        assert got["rounds"] == ref["rounds"], backend
        np.testing.assert_array_equal(got["counts"], ref["counts"],
                                      err_msg=backend)
        assert got[lost_key] == ref[lost_key], backend


def test_drop_policy_fatal_flags_still_raise():
    """Only the channel watermarks soften under drop: late-MMIO and
    store-log overflow are program bugs and still abort."""
    from repro.core import segmentation as sg
    from repro.vp import workloads as wl

    layer = wl.Layer("flt", "t", 8, 8, 4)
    job = wl.riscv_workload(layer)
    descs = [sg.SegmentDesc(cpu=True, dram=True)]
    cfg, states, pending = sg.build(descs, programs=job["programs"],
                                    dram_words=job["dram"], store_log=2,
                                    faults=DROP)
    ctl = Controller(cfg, states, pending, backend="vmap", quantum=20_000)
    with pytest.raises(RuntimeError, match="store-log overflow"):
        ctl.run(max_rounds=100, check_every=2)


def test_generous_caps_under_drop_policy_lose_nothing():
    """With roomy caps the drop policy is inert: results match the
    unfaulted baseline exactly and the loss counters stay zero."""
    got = _run_burst(DROP, "vmap", True)
    base = _run_burst(None, "vmap", True)
    assert got["inbox_lost"] == got["outbox_lost"] == 0
    assert got["rounds"] == base["rounds"]
    np.testing.assert_array_equal(got["counts"], base["counts"])


# ---------------------------------------------------------------------------
# the degradation-sweep driver


def test_degradation_sweep_transport_monotone():
    rates = [0.0, 0.3, 0.7, 1.0]
    res = snn.degradation_sweep(JOB, rates, fault_kind="transport", seed=3)
    assert [r["rate"] for r in res] == rates
    fids = [r["fidelity"] for r in res]
    assert fids[0] == 1.0, "rate 0 must be oracle-exact"
    # nested CRN hashing makes the curve monotone up to a small tolerance
    assert all(fids[i] + 1e-9 >= fids[i + 1] - 0.02
               for i in range(len(fids) - 1)), fids
    assert res[-1]["total_spikes"] < res[0]["total_spikes"]


@pytest.mark.parametrize("kind", ["crossbar", "neuron"])
def test_degradation_sweep_structural(kind):
    res = snn.degradation_sweep(JOB, [0.0, 0.5], fault_kind=kind, seed=3)
    assert res[0]["fidelity"] == 1.0
    assert res[1]["fidelity"] < 1.0
