"""Model API: ``build(cfg)`` -> specs + pure functions, plus the per-cell
input/cache ShapeDtypeStruct + PartitionSpec builders used by the launchers
and the multi-pod dry-run.

Sharding policy
---------------
- activations: batch over ("pod","data"); everything else decided by GSPMD
  from weight specs + a few constraints.
- weights: TP over "model" where the relevant axis divides (see layers.py /
  moe.py / ssm.py spec builders); FSDP over "data" for kimi-k2 expert weights.
- KV caches: batch over data axes when large enough, kv-heads over "model"
  when divisible, sequence over leftover axes (split-KV decode otherwise).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import DTypePolicy, ParamSpec, init_params, shape_dtypes
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.models import layers as L
from repro.models import encdec as ED
from repro.models import transformer as TF
from repro.models.ssm import ssm_state_shape

TP = 16  # model-axis size of the production meshes


def needs_fsdp(cfg: ModelConfig) -> bool:
    """FSDP (data-axis) sharding of expert weights for very large MoE.

    Threshold: total expert params > 20 B — TP-only sharding (16-way) of the
    expert stack would then exceed ~2.5 GB/chip in bf16, so the weights are
    additionally sharded over the data axis and all-gathered per layer.
    """
    if cfg.moe is None:
        return False
    m = cfg.moe
    expert_params = (cfg.n_layers - m.first_k_dense) * m.n_experts * 3 * cfg.d_model * m.d_ff_expert
    return expert_params > 2e10


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    policy: DTypePolicy
    specs: Any

    def init(self, key):
        return init_params(key, self.specs)

    # ---- training ----
    def loss(self, params, batch, mesh=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            h = ED.encdec_loss_forward(cfg, params, batch, self.policy, mesh=mesh)
            aux = jnp.zeros((), jnp.float32)
        else:
            h, _, aux = TF.forward(
                cfg, params, batch, self.policy, mode="train", mesh=mesh, fsdp=needs_fsdp(cfg)
            )
        lg = TF.lm_logits(cfg, params, h, self.policy)
        ce = L.cross_entropy(lg[:, :-1], batch["tokens"][:, 1:])
        return ce + aux, {"ce": ce, "aux": aux}

    # ---- serving ----
    def prefill(self, params, batch, mesh=None):
        """Returns (cache, last-token logits)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            memory = ED.encode(cfg, params, batch["enc_feats"], self.policy, mesh=mesh)
            xkv = ED.cross_kv(cfg, params, memory, self.policy)
            h, self_c = ED.decode_forward(
                cfg, params, batch["tokens"], self.policy, mode="prefill", cache=None,
                xkv=xkv, mesh=mesh,
            )
            cache = {"self": self_c, "cross": xkv}
        else:
            h, cache, _ = TF.forward(
                cfg, params, batch, self.policy, mode="prefill", mesh=mesh,
                fsdp=needs_fsdp(cfg), cache=None,
            )
        lg = TF.lm_logits(cfg, params, h[:, -1:], self.policy)
        return cache, lg

    def decode_step(self, params, cache, batch, pos, mesh=None):
        """One token for every sequence in the batch. Returns (logits, cache)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            h, self_c = ED.decode_forward(
                cfg, params, batch["tokens"], self.policy, mode="decode",
                cache=cache["self"], xkv=cache["cross"], pos=pos, mesh=mesh,
            )
            new_cache = {"self": self_c, "cross": cache["cross"]}
        else:
            h, new_cache, _ = TF.forward(
                cfg, params, batch, self.policy, mode="decode", mesh=mesh,
                fsdp=needs_fsdp(cfg), cache=cache, pos=pos,
            )
        lg = TF.lm_logits(cfg, params, h, self.policy)
        return lg, new_cache


def fsdp_params(cfg: ModelConfig, tp: int = TP) -> bool:
    """Full param FSDP (data-axis sharding of every large weight) when
    TP-only sharding would exceed ~4 GB/chip of raw parameter bytes."""
    from repro.common import param_bytes

    m = build_specs_only(cfg, tp)
    return param_bytes(m) / tp > 4 * 2**30


def build_specs_only(cfg: ModelConfig, tp: int = TP):
    if cfg.family == "encdec":
        return ED.encdec_specs(cfg, tp)
    return TF.decoder_specs(cfg, tp, fsdp=needs_fsdp(cfg))


def build(cfg: ModelConfig, tp: int = TP) -> Model:
    from repro.common import is_spec
    from repro.train.optimizer import zero1_pspec

    policy = DTypePolicy(params=cfg.params_dtype)
    specs = build_specs_only(cfg, tp)
    if fsdp_params(cfg, tp):
        # shard every large weight's biggest free axis over 'data' (ZeRO-3 /
        # FSDP); weights are re-gathered per layer inside the scan by GSPMD.
        def respec(s):
            import numpy as np

            if int(np.prod(s.shape)) < 2**20:
                return s
            return dataclasses.replace(s, pspec=zero1_pspec(s))

        specs = jax.tree.map(respec, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return Model(cfg=cfg, policy=policy, specs=specs)


# ---------------------------------------------------------------------------
# per-cell input specs (ShapeDtypeStruct stand-ins; no allocation)


def _batch_axes(batch: int, min_shards: int = 16):
    return ("pod", "data") if batch >= min_shards else None


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str):
    """Returns (inputs, pspecs) for one (arch, shape) cell.

    ``inputs`` are ShapeDtypeStructs; decode cells also carry the cache via
    ``cache_specs`` (separate function, since it is donated state).
    """
    shape = SHAPES[shape] if isinstance(shape, str) else shape
    b, s = shape.global_batch, shape.seq_len
    bax = _batch_axes(b)
    tok = jnp.int32
    inputs: dict[str, Any] = {}
    pspecs: dict[str, Any] = {}
    if cfg.family == "encdec":
        if shape.kind == "train":
            inputs["enc_feats"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
            inputs["tokens"] = jax.ShapeDtypeStruct((b, s), tok)
        elif shape.kind == "prefill":
            inputs["enc_feats"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
            inputs["tokens"] = jax.ShapeDtypeStruct((b, 448), tok)
        else:  # decode
            inputs["tokens"] = jax.ShapeDtypeStruct((b, 1), tok)
        pspecs = {k: P(bax, *([None] * (len(v.shape) - 1))) for k, v in inputs.items()}
        return inputs, pspecs

    if shape.kind == "decode":
        inputs["tokens"] = jax.ShapeDtypeStruct((b, 1), tok)
        if cfg.mrope:
            inputs["mrope_pos"] = jax.ShapeDtypeStruct((3, b, 1), tok)
    else:
        inputs["tokens"] = jax.ShapeDtypeStruct((b, s), tok)
        if cfg.family == "vlm":
            nv = min(cfg.n_vision_tokens, s // 2)
            inputs["vision_embeds"] = jax.ShapeDtypeStruct((b, nv, cfg.d_model), jnp.bfloat16)
        if cfg.mrope:
            inputs["mrope_pos"] = jax.ShapeDtypeStruct((3, b, s), tok)
    for k, v in inputs.items():
        if k == "mrope_pos":
            pspecs[k] = P(None, bax, None)
        else:
            pspecs[k] = P(bax, *([None] * (len(v.shape) - 1)))
    return inputs, pspecs


def _attn_cache_cell(cfg, batch, seq, n_stack, tp=TP, inner=None):
    """(sds, pspec) for one stacked attention cache entry (k or v)."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    lead = (n_stack,) if inner is None else (n_stack, inner)
    sds = jax.ShapeDtypeStruct(lead + (batch, seq, hkv, dh), jnp.bfloat16)
    bax = _batch_axes(batch)
    hax = "model" if hkv % tp == 0 else None
    if hax and bax:
        seq_ax = None
    elif hax:
        seq_ax = "data"  # long-context, tiny batch: split-KV over data
    elif bax:
        seq_ax = "model"  # heads unshardable: split-KV over model
    else:
        seq_ax = ("data", "model")
    div = tp * tp if isinstance(seq_ax, tuple) else tp
    if seq_ax is not None and seq % div != 0:
        seq_ax = None  # e.g. whisper's 1500-frame cross-attention memory
    pspec = P(*([None] * len(lead)), bax, seq_ax, hax, None)
    return sds, pspec


def _ssm_cache_cell(cfg, batch, n_stack, inner=None, tp=TP):
    shp = ssm_state_shape(cfg, batch)
    lead = (n_stack,) if inner is None else (n_stack, inner)
    bax = _batch_axes(batch)
    lead_p = [None] * len(lead)

    def one(name, s):
        sds = jax.ShapeDtypeStruct(lead + s.shape, s.dtype)
        if name in ("conv", "conv_x"):
            pspec = P(*lead_p, bax, None, "model")
        elif name == "conv_bc":
            pspec = P(*lead_p, bax, None, None)
        elif cfg.ssm.version == 1:  # ssm state (B, din, N)
            pspec = P(*lead_p, bax, "model", None)
        else:  # (B, nh, N, P)
            pspec = P(*lead_p, bax, "model", None, None)
        return sds, pspec

    sds = {k: one(k, v)[0] for k, v in shp.items()}
    ps = {k: one(k, v)[1] for k, v in shp.items()}
    return sds, ps


def cache_specs(cfg: ModelConfig, shape: ShapeConfig | str, tp: int = TP):
    """Decode-cell cache (sds_tree, pspec_tree) matching forward()'s layout."""
    shape = SHAPES[shape] if isinstance(shape, str) else shape
    b, s = shape.global_batch, shape.seq_len
    fam = cfg.family
    if fam == "encdec":
        ksd, kps = _attn_cache_cell(cfg, b, s, cfg.n_layers, tp)
        # prefill encodes the full input (cross length = seq_len, shardable
        # over 'model' — this sharding propagates back into the encoder);
        # standalone decode cells use the native audio-frame memory length
        cross_len = s if shape.kind == "prefill" else cfg.n_audio_frames
        xsd, xps = _attn_cache_cell(cfg, b, cross_len, cfg.n_layers, tp)
        return (
            {"self": (ksd, ksd), "cross": (xsd, xsd)},
            {"self": (kps, kps), "cross": (xps, xps)},
        )
    if fam in ("dense", "vlm"):
        ksd, kps = _attn_cache_cell(cfg, b, s, cfg.n_layers, tp)
        return {"layers": (ksd, ksd)}, {"layers": (kps, kps)}
    if fam == "moe":
        out_s, out_p = {}, {}
        if cfg.moe.first_k_dense:
            ksd, kps = _attn_cache_cell(cfg, b, s, cfg.moe.first_k_dense, tp)
            out_s["dense_layers"], out_p["dense_layers"] = (ksd, ksd), (kps, kps)
        ksd, kps = _attn_cache_cell(cfg, b, s, cfg.n_layers - cfg.moe.first_k_dense, tp)
        out_s["layers"], out_p["layers"] = (ksd, ksd), (kps, kps)
        return out_s, out_p
    if fam == "ssm":
        ssd, sps = _ssm_cache_cell(cfg, b, cfg.n_layers, tp=tp)
        return {"layers": ssd}, {"layers": sps}
    if fam == "hybrid":
        ng = TF.n_groups(cfg)
        ksd, kps = _attn_cache_cell(cfg, b, s, ng, tp)
        ssd, sps = _ssm_cache_cell(cfg, b, ng, inner=cfg.attn_every, tp=tp)
        return (
            {"groups": {"attn": (ksd, ksd), "ssm": ssd}},
            {"groups": {"attn": (kps, kps), "ssm": sps}},
        )
    raise ValueError(fam)
