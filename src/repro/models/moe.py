"""Token-choice top-k Mixture-of-Experts with expert parallelism.

Two execution paths:

- ``ep_moe`` (production): a ``jax.shard_map`` region.  Activations are
  sharded over the batch axes and *replicated* over ``model``; experts are
  sharded over ``model`` (EP).  Each model-rank routes the local tokens,
  scatters the ones assigned to *its* experts into an (E_local, C, d) buffer
  (sort-free cumsum dispatch — no (T,E,C) one-hot einsum, so dispatch adds no
  matmul FLOPs), runs the expert GEMMs, gathers results back per token, adds
  the shared-expert partial product and psums over ``model`` — a single
  all-reduce per MoE layer, exactly like a Megatron TP FFN.
- ``dense_moe`` (fallback for tests / no-mesh execution): mathematically
  identical capacity-less routing via masked per-expert compute.

Capacity: ``C = ceil(top_k·T·cf/E)`` (GShard-style, overflow dropped) for
large T; for small-T decode shapes C is set to ``top_k·T`` so routing is
provably dropless (inference must not drop tokens).

Expert weights may additionally be sharded over the ``data`` axis
(FSDP-style, needed by kimi-k2's 1T params); they are all-gathered per layer
inside the shard_map region.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import DTypePolicy, ParamSpec
from repro.compat import shard_map
from repro.models.layers import DATA_AXES, mlp_specs, apply_mlp


def moe_specs(cfg, tp: int, fsdp: bool = False):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = cfg.params_dtype
    # experts sharded over model; optionally FSDP over data on the ff axis
    ff_ax = "data" if fsdp else None
    s = {
        "router": ParamSpec((d, e), jnp.float32, P(), init="small"),
        "w_in": ParamSpec((e, d, f), dt, P("model", None, ff_ax)),
        "w_gate": ParamSpec((e, d, f), dt, P("model", None, ff_ax)),
        "w_out": ParamSpec((e, f, d), dt, P("model", ff_ax, None)),
    }
    if m.n_shared:
        fs = f * m.n_shared
        s["shared"] = {
            "w_in": ParamSpec((d, fs), dt, P(None, "model")),
            "w_gate": ParamSpec((d, fs), dt, P(None, "model")),
            "w_out": ParamSpec((fs, d), dt, P("model", None)),
        }
    return s


def _route(cfg, p, x2d):
    """x2d (T, d) -> gates (T, k) fp32, experts (T, k) int32, aux loss scalar."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f_e = jnp.zeros((m.n_experts,), jnp.float32)
    for k in range(m.top_k):
        f_e = f_e + jnp.bincount(
            experts[:, k], length=m.n_experts, minlength=m.n_experts
        ).astype(jnp.float32)
    f_e = f_e / (x2d.shape[0] * m.top_k)
    aux = m.n_experts * jnp.sum(f_e * probs.mean(0)) * m.router_aux_coef
    return gates, experts, aux


def _positions_in_expert(experts, n_experts):
    """Per-(token,k) slot index within its expert (cumsum dispatch, no sort).

    Token-major, k-minor arrival order; memory O(T·E) int32 per k-slice.
    """
    t, kk = experts.shape
    base = jnp.zeros((n_experts,), jnp.int32)
    pos = []
    for k in range(kk):
        oh = jax.nn.one_hot(experts[:, k], n_experts, dtype=jnp.int32)  # (T, E)
        within = jnp.cumsum(oh, axis=0) - 1  # occurrence index per expert
        pos.append((within * oh).sum(-1) + jnp.take(base, experts[:, k]))
        base = base + oh.sum(0)
    return jnp.stack(pos, axis=1)  # (T, k)


def _capacity(cfg, t_local: int) -> int:
    m = cfg.moe
    if m.top_k * t_local <= 4096:  # decode-ish: make routing dropless
        return m.top_k * t_local
    c = math.ceil(m.top_k * t_local * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)


def _expert_ffn(cfg, w_in, w_gate, w_out, buf, cdt):
    h_in = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(cdt))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cdt))) * h_in
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(cdt))


def ep_moe(cfg, p, x, policy: DTypePolicy, mesh, fsdp: bool = False):
    """Expert-parallel MoE via shard_map. x (B, S, d) sharded over batch axes."""
    m = cfg.moe
    cdt = policy.compute
    e_total = m.n_experts
    tp = mesh.shape["model"]
    assert e_total % tp == 0, (e_total, tp)
    e_loc = e_total // tp
    ff_ax = "data" if fsdp else None

    def local_moe(p, x):
        b, s, d = x.shape
        t = b * s
        x2 = x.reshape(t, d)
        gates, experts, aux = _route(cfg, p, x2)
        cap = _capacity(cfg, t)
        pos = _positions_in_expert(experts, e_total)  # (T, k)
        rank = jax.lax.axis_index("model")
        e_lo = rank * e_loc
        local = (experts >= e_lo) & (experts < e_lo + e_loc) & (pos < cap)
        slot = jnp.where(local, (experts - e_lo) * cap + pos, e_loc * cap)  # dummy row
        # dispatch: scatter token rows into (E_local*C (+1 dummy), d)
        buf = jnp.zeros((e_loc * cap + 1, d), cdt)
        for k in range(m.top_k):
            buf = buf.at[slot[:, k]].add(jnp.where(local[:, k, None], x2.astype(cdt), 0))
        w_in, w_gate, w_out = p["w_in"], p["w_gate"], p["w_out"]
        if fsdp:  # gather the data-sharded ff axis of this layer's experts
            w_in = jax.lax.all_gather(w_in, "data", axis=2, tiled=True)
            w_gate = jax.lax.all_gather(w_gate, "data", axis=2, tiled=True)
            w_out = jax.lax.all_gather(w_out, "data", axis=1, tiled=True)
        out_rows = _expert_ffn(
            cfg, w_in, w_gate, w_out, buf[:-1].reshape(e_loc, cap, d), cdt
        ).reshape(e_loc * cap, d)
        out_rows = jnp.concatenate([out_rows, jnp.zeros((1, d), cdt)], axis=0)
        # combine: gather each (token, k)'s row, weight by gate
        y = jnp.zeros((t, d), cdt)
        for k in range(m.top_k):
            contrib = jnp.take(out_rows, slot[:, k], axis=0)
            y = y + contrib * (gates[:, k, None].astype(cdt) * local[:, k, None])
        if m.n_shared:
            y = y + apply_mlp(cfg, p["shared"], x2, policy)  # partial over ff shards
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, all_axes)  # replicated across the whole mesh
        return y.reshape(b, s, d), aux

    pspecs = {
        "router": P(),
        "w_in": P("model", None, ff_ax),
        "w_gate": P("model", None, ff_ax),
        "w_out": P("model", ff_ax, None),
    }
    if m.n_shared:
        pspecs["shared"] = {
            "w_in": P(None, "model"),
            "w_gate": P(None, "model"),
            "w_out": P("model", None),
        }
    avail = set(mesh.axis_names)
    baxes = tuple(a for a in DATA_AXES if a in avail)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in avail)
    fn = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(pspecs, P(baxes, None, None)),
        out_specs=(P(baxes, None, None), P()),
    )
    return fn(p, x)


def ep_moe_decode(cfg, p, x, policy: DTypePolicy, mesh, fsdp: bool):
    """Decode-shape MoE: replicated-token 2-D expert tensor parallelism.

    §Perf hillclimb (kimi-k2 / llama4 decode): the FSDP train layout shards
    expert ff over 'data'; gathering weights per layer at decode moves GBs
    per token step.  Tokens are tiny at decode — so move *tokens* instead:
    all-gather the (≤128 × d_model) token batch over 'data', let every chip
    compute its (expert-subset × ff-slice) contribution with its resident
    weight shard (the silu gate is elementwise in ff, so ff-slicing is
    exact), psum over (data, model), and slice back the local rows.
    Weight traffic: zero.  Collective traffic: MBs instead of GBs.
    """
    m = cfg.moe
    cdt = policy.compute
    tp = mesh.shape["model"]
    dp = mesh.shape.get("data", 1)
    e_loc = m.n_experts // tp
    avail = set(mesh.axis_names)
    baxes = tuple(a for a in DATA_AXES if a in avail)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in avail)

    def local(p, x):
        b, s, d = x.shape  # local rows
        x2 = x.reshape(b * s, d)
        x_all = jax.lax.all_gather(x2, "data", axis=0, tiled=True)  # (T_pod, d)
        t_all = x_all.shape[0]
        gates, experts, aux = _route(cfg, p, x_all)
        # capacity: 8× the balanced expectation (bounded-overflow — routing
        # hot-spots beyond 8× drop, as production decode engines accept);
        # the fully-dropless cap (top_k·T) blew the dispatch buffers up 16×
        # and put the memory term above the weights themselves (§Perf)
        expected = -(-m.top_k * t_all // m.n_experts)
        cap = min(m.top_k * t_all, max(32, 8 * expected))
        pos = _positions_in_expert(experts, m.n_experts)
        rank_m = jax.lax.axis_index("model")
        e_lo = rank_m * e_loc
        mine = (experts >= e_lo) & (experts < e_lo + e_loc)
        slot = jnp.where(mine, (experts - e_lo) * cap + pos, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, d), cdt)
        for k in range(m.top_k):
            buf = buf.at[slot[:, k]].add(jnp.where(mine[:, k, None], x_all.astype(cdt), 0))
        buf = buf[:-1].reshape(e_loc, cap, d)
        # resident ff slice (fsdp: f/dp per chip; else full f)
        h_in = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(cdt))
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cdt))) * h_in
        out_rows = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cdt)).reshape(e_loc * cap, d)
        if not fsdp and dp > 1:
            out_rows = out_rows / dp  # full-f replicas would be summed dp times
        out_rows = jnp.concatenate([out_rows, jnp.zeros((1, d), cdt)], axis=0)
        y = jnp.zeros((t_all, d), cdt)
        for k in range(m.top_k):
            contrib = jnp.take(out_rows, slot[:, k], axis=0)
            y = y + contrib * (gates[:, k, None].astype(cdt) * mine[:, k, None])
        if m.n_shared:
            ysh = apply_mlp(cfg, p["shared"], x_all, policy)  # partial over model-ff
            y = y + (ysh / dp if dp > 1 else ysh)
        y = jax.lax.psum(y, ("data", "model") if dp > 1 else ("model",))
        # slice back this data-rank's rows
        rank_d = jax.lax.axis_index("data") if dp > 1 else 0
        y_loc = jax.lax.dynamic_slice_in_dim(y, rank_d * b * s, b * s, axis=0)
        aux = jax.lax.pmean(aux, all_axes)
        return y_loc.reshape(b, s, d), aux

    ff_ax = "data" if fsdp else None
    pspecs = {
        "router": P(),
        "w_in": P("model", None, ff_ax),
        "w_gate": P("model", None, ff_ax),
        "w_out": P("model", ff_ax, None),
    }
    if m.n_shared:
        pspecs["shared"] = {
            "w_in": P(None, "model"),
            "w_gate": P(None, "model"),
            "w_out": P("model", None),
        }
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pspecs, P(baxes, None, None)),
        out_specs=(P(baxes, None, None), P()),
    )
    return fn(p, x)


def dense_moe(cfg, p, x, policy: DTypePolicy):
    """Reference path: per-expert masked dense compute (no capacity, no drop)."""
    m = cfg.moe
    cdt = policy.compute
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    gates, experts, aux = _route(cfg, p, x2)
    weight = jnp.zeros((b * s, m.n_experts), jnp.float32)
    for k in range(m.top_k):
        weight = weight + jax.nn.one_hot(experts[:, k], m.n_experts) * gates[:, k, None]
    h_in = jnp.einsum("td,edf->tef", x2.astype(cdt), p["w_in"].astype(cdt))
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x2.astype(cdt), p["w_gate"].astype(cdt))) * h_in
    y_e = jnp.einsum("tef,efd->ted", h, p["w_out"].astype(cdt))
    y = jnp.einsum("ted,te->td", y_e, weight.astype(cdt))
    if m.n_shared:
        y = y + apply_mlp(cfg, p["shared"], x2, policy)
    return y.reshape(b, s, d), aux


def apply_moe(cfg, p, x, policy, mesh=None, fsdp=False, decode=False):
    if mesh is not None and "model" in mesh.axis_names and cfg.moe.n_experts % mesh.shape["model"] == 0:
        if decode and x.shape[0] * x.shape[1] <= 4096:
            return ep_moe_decode(cfg, p, x, policy, mesh, fsdp=fsdp)
        return ep_moe(cfg, p, x, policy, mesh, fsdp=fsdp)
    return dense_moe(cfg, p, x, policy)
