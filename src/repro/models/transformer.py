"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM families.

Layers are *scanned* (stacked params with a leading L axis) — compile time
stays flat in depth, which matters when lowering 61–88-layer models for 512
devices.  Heterogeneous structure is expressed as a few homogeneous scans:

- moe:    ``first_k_dense`` dense layers (own scan) + scanned MoE layers
- hybrid: outer scan over groups of (shared-weight attention block +
          ``attn_every`` Mamba-2 layers), inner scan over the group
- vlm:    dense layers + vision-embed merge + M-RoPE angles

Modes: ``train`` (dense causal attention, remat), ``prefill`` (chunked flash,
returns KV caches), ``decode`` (grouped-query attention against a cache whose
sequence axis may be sharded — split-KV decoding).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import DTypePolicy, ParamSpec, with_sharding
from repro.models import layers as L
from repro.models.moe import apply_moe, moe_specs
from repro.models.ssm import ssm_block, mamba1_specs, mamba2_specs, ssm_state_shape


def stack_specs(tree, n: int):
    """Prepend a layer axis of size n to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, s.dtype, P(None, *s.pspec), init=s.init),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _attn_block_specs(cfg, tp):
    return {"ln": L.norm_specs(cfg), "attn": L.attn_specs(cfg, tp)}


def _dense_layer_specs(cfg, tp, d_ff=None):
    return {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg, tp),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg, tp, d_ff=d_ff),
    }


def _moe_layer_specs(cfg, tp, fsdp):
    return {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg, tp),
        "ln2": L.norm_specs(cfg),
        "moe": moe_specs(cfg, tp, fsdp=fsdp),
    }


def _ssm_layer_specs(cfg, tp):
    sfn = mamba1_specs if cfg.ssm.version == 1 else mamba2_specs
    return {"ln": L.norm_specs(cfg), "ssm": sfn(cfg, tp)}


def n_groups(cfg):
    return cfg.n_layers // cfg.attn_every


def decoder_specs(cfg, tp: int = 16, fsdp: bool = False):
    s = {"embed": L.embed_specs(cfg, tp), "final_norm": L.norm_specs(cfg)}
    s.update(L.logits_specs(cfg, tp))  # adds "w" unless tied
    fam = cfg.family
    if fam in ("dense", "vlm"):
        s["layers"] = stack_specs(_dense_layer_specs(cfg, tp), cfg.n_layers)
    elif fam == "moe":
        m = cfg.moe
        if m.first_k_dense:
            s["dense_layers"] = stack_specs(
                _dense_layer_specs(cfg, tp, d_ff=m.d_ff_dense), m.first_k_dense
            )
        s["layers"] = stack_specs(
            _moe_layer_specs(cfg, tp, fsdp), cfg.n_layers - m.first_k_dense
        )
    elif fam == "ssm":
        s["layers"] = stack_specs(_ssm_layer_specs(cfg, tp), cfg.n_layers)
    elif fam == "hybrid":
        s["shared_attn"] = _attn_block_specs(cfg, tp)
        s["layers"] = stack_specs(
            stack_specs(_ssm_layer_specs(cfg, tp), cfg.attn_every), n_groups(cfg)
        )
    else:
        raise ValueError(fam)
    return s


# ---------------------------------------------------------------------------
# sub-block applications


def _grouped_decode_attention(q, k_cache, v_cache, length):
    """q (B,1,Hq,Dh) vs cache (B,Smax,Hkv,Dh); no kv expansion (GQA grouped).

    Works with the cache sequence axis sharded (split-KV decode): the softmax
    reductions over the sharded axis become partial-max/sum collectives.
    """
    b, _, hq, dh = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32) * scale
    mask = jnp.arange(k_cache.shape[1])[None, None, None, None, :] < length
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_cache)
    return o.reshape(b, 1, hq, dh)


def attn_apply(cfg, p, x, policy, *, mode, angles, cache=None, pos=None):
    """Attention sub-block body. Returns (out, new_cache).

    new_cache: (k, v) new entries for prefill; updated (k_cache, v_cache) for
    decode; None for train.
    """
    q, k, v = L.qkv_project(cfg, p["attn"], x, policy, angles=angles)
    nh = q.shape[2]  # possibly pad-extended for TP divisibility
    if mode == "train":
        ke, ve = L.expand_kv(k, nh), L.expand_kv(v, nh)
        if cfg.attn_impl == "flash" and q.shape[1] >= 512:
            o = L.flash_attention_train(q, ke, ve)
        else:
            o = L.dense_attention(q, ke, ve, causal=True)
        return L.attn_out(p["attn"], L.mask_pad_heads(cfg, o), policy), None
    if mode == "prefill":
        o = L.flash_prefill_attention(q, L.expand_kv(k, nh), L.expand_kv(v, nh))
        return L.attn_out(p["attn"], L.mask_pad_heads(cfg, o), policy), (k, v)
    if mode == "decode":
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1
        )
        o = _grouped_decode_attention(q, k_cache, v_cache, pos + 1)
        return L.attn_out(p["attn"], L.mask_pad_heads(cfg, o), policy), (k_cache, v_cache)
    raise ValueError(mode)


def dense_layer(cfg, p, x, policy, *, mode, angles, cache=None, pos=None, mesh=None):
    a, new_cache = attn_apply(
        cfg, p, L.apply_norm(cfg, p["ln1"], x), policy,
        mode=mode, angles=angles, cache=cache, pos=pos,
    )
    x = x + a
    x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x), policy)
    return x, new_cache


def moe_layer(cfg, p, x, policy, *, mode, angles, cache=None, pos=None, mesh=None, fsdp=False):
    a, new_cache = attn_apply(
        cfg, p, L.apply_norm(cfg, p["ln1"], x), policy,
        mode=mode, angles=angles, cache=cache, pos=pos,
    )
    x = x + a
    y, aux = apply_moe(
        cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x), policy, mesh=mesh, fsdp=fsdp,
        decode=(mode == "decode"),
    )
    return x + y, new_cache, aux


def ssm_layer(cfg, p, x, policy, state=None):
    y, new_state = ssm_block(cfg, p["ssm"], L.apply_norm(cfg, p["ln"], x), policy, state=state)
    return x + y, new_state


# ---------------------------------------------------------------------------
# forward


def _embed_and_angles(cfg, params, batch, policy, mode, pos):
    tokens = batch["tokens"]
    h = L.embed(params["embed"], tokens, policy) * math.sqrt(cfg.d_model)
    if cfg.family == "vlm" and "vision_embeds" in batch and mode != "decode":
        nv = batch["vision_embeds"].shape[1]
        h = jnp.concatenate([batch["vision_embeds"].astype(h.dtype), h[:, nv:]], axis=1)
    if cfg.attn_free:
        return h, None
    if cfg.mrope and "mrope_pos" in batch:
        angles = L.mrope_angles(
            batch["mrope_pos"], cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )
    else:
        b, s = tokens.shape
        if mode == "decode":
            positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        angles = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    return h, angles


def forward(cfg, params, batch, policy, *, mode, mesh=None, fsdp=False, cache=None, pos=None):
    """Core forward.  Returns (hidden, new_cache, aux_loss).

    ``cache`` / ``new_cache`` pytrees are stacked over the scanned layer axis:
      dense/vlm: {"layers": (k, v)}           each (L, B, S, Hkv, Dh)
      moe:       {"dense_layers": ..., "layers": ...}
      ssm:       {"layers": ssm-state tree}   leaves (L, B, ...)
      hybrid:    {"groups": {"attn": (k, v), "ssm": state}}  (G, ...) / (G, E, ...)
    For prefill, pass ``cache`` = preallocated zero caches (entries are
    written at [0:S]); for train pass None.
    """
    h, angles = _embed_and_angles(cfg, params, batch, policy, mode, pos)
    h = with_sharding(h, mesh, P(L.DATA_AXES, None, None))
    aux0 = jnp.zeros((), jnp.float32)
    remat = cfg.remat != "none" and mode == "train"
    if not remat:
        ckpt = lambda f: f
    elif cfg.remat == "save_dots":
        # §Perf: saving matmul outputs (cheap per chip under TP) lets the
        # backward skip re-running the forward's fusion chains — trades a
        # little HBM for a large cut in recompute traffic.
        ckpt = partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    else:
        ckpt = jax.checkpoint
    constrain = lambda x: with_sharding(x, mesh, P(L.DATA_AXES, None, None))
    fam = cfg.family
    new_cache = {}
    write_pos = 0 if mode == "prefill" else pos

    if fam in ("dense", "vlm", "moe"):
        def dense_body(x, xs):
            lp, c = xs
            x, c_out = dense_layer(
                cfg, lp, x, policy, mode=mode, angles=angles, cache=c, pos=write_pos, mesh=mesh
            )
            return constrain(x), c_out

        def moe_body(carry, xs):
            x, aux = carry
            lp, c = xs
            x, c_out, a = moe_layer(
                cfg, lp, x, policy, mode=mode, angles=angles, cache=c,
                pos=write_pos, mesh=mesh, fsdp=fsdp,
            )
            return (constrain(x), aux + a), c_out

        aux = aux0
        if fam == "moe" and cfg.moe.first_k_dense:
            c = cache["dense_layers"] if (cache is not None and mode == "decode") else None
            h, c_out = jax.lax.scan(ckpt(dense_body), h, (params["dense_layers"], c))
            new_cache["dense_layers"] = c_out
        key_cache = cache["layers"] if (cache is not None and mode == "decode") else None
        if fam == "moe":
            (h, aux), c_out = jax.lax.scan(
                ckpt(moe_body), (h, aux0), (params["layers"], key_cache)
            )
        else:
            h, c_out = jax.lax.scan(ckpt(dense_body), h, (params["layers"], key_cache))
        new_cache["layers"] = c_out
        return _finish(cfg, params, h), (new_cache if mode != "train" else None), aux

    if fam == "ssm":
        def ssm_body(x, xs):
            lp, st = xs
            x, st_out = ssm_layer(cfg, lp, x, policy, state=st)
            return constrain(x), st_out

        st_in = cache["layers"] if cache is not None else None
        h, st_out = jax.lax.scan(ckpt(ssm_body), h, (params["layers"], st_in))
        return _finish(cfg, params, h), ({"layers": st_out} if mode != "train" else None), aux0

    if fam == "hybrid":
        shared = params["shared_attn"]

        def group_body(x, xs):
            gp, gc = xs
            a, attn_c = attn_apply(
                cfg, shared, L.apply_norm(cfg, shared["ln"], x), policy,
                mode=mode, angles=angles, cache=gc["attn"], pos=write_pos,
            )
            x = x + a

            def inner(x2, xs2):
                lp, st = xs2
                x2, st_out = ssm_layer(cfg, lp, x2, policy, state=st)
                return x2, st_out

            x, st_out = jax.lax.scan(inner, x, (gp, gc["ssm"]))
            return constrain(x), {"attn": attn_c, "ssm": st_out}

        if cache is not None:
            gc_in = cache["groups"]
        else:  # train: zero ssm states, no attn cache
            gc_in = {
                "attn": None,
                "ssm": _zero_ssm_states(cfg, h.shape[0], n_groups(cfg), inner=cfg.attn_every),
            }
        h, gc_out = jax.lax.scan(ckpt(group_body), h, (params["layers"], gc_in))
        return _finish(cfg, params, h), ({"groups": gc_out} if mode != "train" else None), aux0

    raise ValueError(fam)


def _zero_ssm_states(cfg, batch, n, inner=None):
    shp = ssm_state_shape(cfg, batch)
    lead = (n,) if inner is None else (n, inner)
    return jax.tree.map(lambda s: jnp.zeros(lead + s.shape, s.dtype), shp)


def _finish(cfg, params, h):
    return L.apply_norm(cfg, params["final_norm"], h)


def lm_logits(cfg, params, h, policy):
    head = {"w": params["w"]} if "w" in params else {}
    return L.logits(cfg, head, params["embed"], h, policy)
