"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD, chunked).

Training/prefill use a chunked formulation: a sequential ``lax.scan`` over
chunks carrying the SSM state, with an intra-chunk associative scan (Mamba-1)
or the quadratic-within-chunk SSD matrix form (Mamba-2).  Decode is a single
O(1) state update — context length never enters the cost, which is why the
``long_500k`` cell is runnable for these families.

Sharding: the inner dimension (``d_inner`` / heads) shards over ``model``;
state tensors are tiny.  The x-projection contracts over the sharded
``d_inner`` axis (psum inserted by GSPMD), mirroring a Megatron FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import DTypePolicy, ParamSpec


def _dinner(cfg):
    return cfg.ssm.expand * cfg.d_model


# ---------------------------------------------------------------------------
# Mamba-1


def mamba1_specs(cfg, tp: int):
    s = cfg.ssm
    d, din, n = cfg.d_model, _dinner(cfg), s.d_state
    dtr = s.dt_rank or d // 16
    dt = cfg.params_dtype
    return {
        "in_proj": ParamSpec((d, 2 * din), dt, P(None, "model")),
        "conv_w": ParamSpec((s.d_conv, din), dt, P(None, "model"), init="small"),
        "conv_b": ParamSpec((din,), jnp.float32, P("model"), init="zeros"),
        "x_proj": ParamSpec((din, dtr + 2 * n), dt, P("model", None)),
        "dt_proj": ParamSpec((dtr, din), dt, P(None, "model"), init="small"),
        "dt_bias": ParamSpec((din,), jnp.float32, P("model"), init="zeros"),
        "a_log": ParamSpec((din, n), jnp.float32, P("model", None), init="ones"),
        "d_skip": ParamSpec((din,), jnp.float32, P("model"), init="ones"),
        "out_proj": ParamSpec((din, d), dt, P("model", None)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x (B,S,C), w (K,C). state (B,K-1,C) for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1) :, :]
    return y + b.astype(x.dtype), new_state


def _mamba1_core(cfg, p, xin, h0, policy):
    """xin (B,S,din) post-conv activations; h0 (B,din,N) fp32. Chunked scan.

    §Perf hillclimb (falcon-mamba train/prefill): the (B,S,din,N) decay/drive
    tensors are N× the activations — materializing them at full sequence
    length made the mamba cells ~300× memory-bound.  They are now expanded
    *per chunk inside the scan body* (and rematerialized in backward via
    jax.checkpoint), so HBM sees only the (B,S,din)-sized inputs/outputs plus
    transient (B,chunk,din,N) tiles.  The Pallas ssm_scan kernel is the
    per-device production form of the same fusion.
    """
    s = cfg.ssm
    n = s.d_state
    dtr = s.dt_rank or cfg.d_model // 16
    cdt = policy.compute
    b, seq, din = xin.shape
    chunk = min(s.chunk, seq)
    assert seq % chunk == 0
    xbc = xin.astype(cdt) @ p["x_proj"].astype(cdt)  # (B,S,dtr+2N), psum over din
    dt_in, bmat, cmat = jnp.split(xbc.astype(jnp.float32), [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])  # (din, N)

    @jax.checkpoint
    def chunk_step(h, inputs):
        dt_c, x_c, b_c, c_c = inputs  # (B,c,din) (B,c,din) (B,c,N) (B,c,N)
        da_c = jnp.exp(dt_c[..., None] * a)  # (B,c,din,N) — transient
        dbx_c = (dt_c * x_c)[..., None] * b_c[:, :, None, :]

        # NOTE (§Perf iteration 2, refuted): replacing this associative scan
        # with a sequential within-chunk lax.scan *increased* the measured
        # HLO traffic 6× (per-step while-loop boundaries defeat fusion in
        # XLA:CPU HLO); the log-depth sweep keeps tensors inside fusions.
        # The true register-resident form is the Pallas ssm_scan kernel.
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        cum_a, part = jax.lax.associative_scan(comb, (da_c, dbx_c), axis=1)
        states = cum_a * h[:, None] + part  # (B,c,din,N)
        y = jnp.einsum("bsdn,bsn->bsd", states, c_c)
        return states[:, -1], y

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, seq // chunk, chunk, *t.shape[2:]), 1, 0)

    h_last, y = jax.lax.scan(
        chunk_step, h0,
        (to_chunks(dt), to_chunks(xin.astype(jnp.float32)), to_chunks(bmat), to_chunks(cmat)),
    )
    y = jnp.moveaxis(y, 0, 1).reshape(b, seq, din)
    y = y + xin.astype(jnp.float32) * p["d_skip"]
    return y, h_last


def mamba1_block(cfg, p, x, policy: DTypePolicy, state=None):
    """Full block. state = None (train/prefill, h0=0) or dict for decode carry."""
    cdt = policy.compute
    b, seq, _ = x.shape
    din = _dinner(cfg)
    xz = x.astype(cdt) @ p["in_proj"].astype(cdt)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    h0 = (
        jnp.zeros((b, din, cfg.ssm.d_state), jnp.float32)
        if state is None
        else state["ssm"]
    )
    xin, new_conv = _causal_conv(xin, p["conv_w"].astype(cdt), p["conv_b"], conv_state)
    xin = jax.nn.silu(xin)
    y, h_last = _mamba1_core(cfg, p, xin, h0, policy)
    out = (y.astype(cdt) * jax.nn.silu(z)) @ p["out_proj"].astype(cdt)
    return out, {"conv": new_conv, "ssm": h_last}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)


def mamba2_specs(cfg, tp: int):
    s = cfg.ssm
    d, din, n = cfg.d_model, _dinner(cfg), s.d_state
    nh = din // s.head_dim
    dt = cfg.params_dtype
    # x/z projection shards over model (shard boundaries align with heads);
    # the small B/C/dt projection stays replicated to avoid mid-axis resharding.
    return {
        "in_proj": ParamSpec((d, 2 * din), dt, P(None, "model")),
        "bcdt_proj": ParamSpec((d, 2 * n + nh), dt, P(None, None)),
        "conv_x_w": ParamSpec((s.d_conv, din), dt, P(None, "model"), init="small"),
        "conv_x_b": ParamSpec((din,), jnp.float32, P("model"), init="zeros"),
        "conv_bc_w": ParamSpec((s.d_conv, 2 * n), dt, P(None, None), init="small"),
        "conv_bc_b": ParamSpec((2 * n,), jnp.float32, P(), init="zeros"),
        "a_log": ParamSpec((nh,), jnp.float32, P(), init="ones"),
        "dt_bias": ParamSpec((nh,), jnp.float32, P(), init="zeros"),
        "d_skip": ParamSpec((nh,), jnp.float32, P(), init="ones"),
        "norm_scale": ParamSpec((din,), jnp.float32, P("model"), init="ones"),
        "out_proj": ParamSpec((din, d), dt, P("model", None)),
    }


def _ssd_core(cfg, xh, bmat, cmat, dt, a_log, h0):
    """Chunked SSD. xh (B,S,H,P) fp32, bmat/cmat (B,S,N), dt (B,S,H), h0 (B,H,N,P)."""
    s = cfg.ssm
    b, seq, nh, pd = xh.shape
    n = bmat.shape[-1]
    chunk = min(s.chunk, seq)
    assert seq % chunk == 0
    nchunks = seq // chunk
    la = -jnp.exp(a_log) * dt  # (B,S,H) log decay per step (negative)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, nchunks, chunk, *t.shape[2:]), 1, 0)

    def chunk_step(h, inputs):
        xc, bc, cc, dtc, lac = inputs  # (B,c,H,P) (B,c,N) (B,c,N) (B,c,H) (B,c,H)
        cs = jnp.cumsum(lac, axis=1)  # (B,c,H) cumulative log decay
        # intra-chunk: Y[i] = sum_{j<=i} C_i·B_j dt_j exp(cs_i - cs_j) x_j
        decay = cs[:, :, None, :] - cs[:, None, :, :]  # (B,i,j,H)
        ii = jnp.arange(chunk)
        mask = ii[:, None] >= ii[None, :]
        gate = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cc, bc)[:, :, :, None] * gate  # (B,i,j,H)
        y = jnp.einsum("bijh,bjh,bjhp->bihp", scores, dtc, xc)
        # inter-chunk: contribution of carry state
        y = y + jnp.einsum("bin,bih,bhnp->bihp", cc, jnp.exp(cs), h)
        # new state
        dec_end = jnp.exp(cs[:, -1:, :] - cs)  # (B,c,H)
        st = jnp.einsum("bjn,bjh,bjhp->bhnp", bc, dtc * dec_end, xc)
        h_new = jnp.exp(cs[:, -1])[:, :, None, None] * h + st
        return h_new, y

    h_last, y = jax.lax.scan(
        chunk_step, h0, (to_chunks(xh), to_chunks(bmat), to_chunks(cmat), to_chunks(dt), to_chunks(la))
    )
    y = jnp.moveaxis(y, 0, 1).reshape(b, seq, nh, pd)
    return y, h_last


def mamba2_block(cfg, p, x, policy: DTypePolicy, state=None):
    s = cfg.ssm
    cdt = policy.compute
    b, seq, _ = x.shape
    din, n = _dinner(cfg), s.d_state
    nh, pd = din // s.head_dim, s.head_dim
    xz = x.astype(cdt) @ p["in_proj"].astype(cdt)
    xin, z = jnp.split(xz, 2, axis=-1)
    bcdt = x.astype(cdt) @ p["bcdt_proj"].astype(cdt)
    bc, dt_in = bcdt[..., : 2 * n], bcdt[..., 2 * n :]
    xin, new_conv_x = _causal_conv(
        xin, p["conv_x_w"].astype(cdt), p["conv_x_b"], None if state is None else state["conv_x"]
    )
    bc, new_conv_bc = _causal_conv(
        bc, p["conv_bc_w"].astype(cdt), p["conv_bc_b"], None if state is None else state["conv_bc"]
    )
    xin = jax.nn.silu(xin)
    bc = jax.nn.silu(bc)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = xin.astype(jnp.float32).reshape(b, seq, nh, pd)
    h0 = (
        jnp.zeros((b, nh, n, pd), jnp.float32)
        if state is None
        else state["ssm"]
    )
    y, h_last = _ssd_core(cfg, xh, bmat.astype(jnp.float32), cmat.astype(jnp.float32), dt, p["a_log"], h0)
    y = y + xh * (dt * p["d_skip"])[..., None]  # dt-scaled skip (Mamba-2 D term)
    y = y.reshape(b, seq, din)
    # gated RMSNorm then out-projection
    yz = y * jax.nn.silu(z.astype(jnp.float32))
    var = (yz * yz).mean(-1, keepdims=True)
    yz = yz * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]
    out = yz.astype(cdt) @ p["out_proj"].astype(cdt)
    return out, {"conv_x": new_conv_x, "conv_bc": new_conv_bc, "ssm": h_last}


def ssm_block(cfg, p, x, policy, state=None):
    if cfg.ssm.version == 1:
        return mamba1_block(cfg, p, x, policy, state)
    return mamba2_block(cfg, p, x, policy, state)


def ssm_state_shape(cfg, batch: int):
    """Decode-state ShapeDtypeStructs for one layer."""
    s = cfg.ssm
    din = _dinner(cfg)
    if s.version == 1:
        return {
            "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, din), jnp.bfloat16),
            "ssm": jax.ShapeDtypeStruct((batch, din, s.d_state), jnp.float32),
        }
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, s.d_conv - 1, din), jnp.bfloat16),
        "conv_bc": jax.ShapeDtypeStruct((batch, s.d_conv - 1, 2 * s.d_state), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct(
            (batch, din // s.head_dim, s.d_state, s.head_dim), jnp.float32
        ),
    }
