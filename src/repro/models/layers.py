"""Core model building blocks: norms, RoPE/M-RoPE, GQA attention, MLP, embeddings.

Conventions
-----------
- params are nested dicts; each module has ``<module>_specs(cfg, tp)`` returning a
  ``ParamSpec`` tree (shape/dtype/PartitionSpec) and ``<module>(params, ...)`` apply fns.
- activations: (batch, seq, d_model); attention heads live in (B, S, H, Dh).
- ``tp`` is the model-axis size used to *decide* sharding (divisibility policy);
  PartitionSpecs always name the ``model`` axis — on a 1-device test mesh they
  are simply inert.
- matmuls run in ``policy.compute`` (bf16); softmax/reductions in fp32.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import DTypePolicy, ParamSpec

DATA_AXES = ("data", "pod")  # batch shards over both; 'pod' absent on 1-pod meshes
# (data-major order matches the device order shard_map's manual mode expects
#  on the (pod, data, model) mesh — pod-major triggers an SPMD full-remat)


def batch_pspec(*rest):
    return P(DATA_AXES, *rest)


# ---------------------------------------------------------------------------
# norms


def norm_specs(cfg, d=None):
    d = d or cfg.d_model
    s = {"scale": ParamSpec((d,), jnp.float32, P(), init="ones")}
    if cfg.norm == "layernorm":
        s["bias"] = ParamSpec((d,), jnp.float32, P(), init="zeros")
    return s


def apply_norm(cfg, p, x, eps=1e-5):
    if getattr(cfg, "fast_norm", False) and cfg.norm == "rmsnorm":
        # §Perf: stats in fp32, normalization multiply in bf16 — the fp32
        # activation-sized fusion chains around every norm dominate the
        # memory roofline term once attention scores are streamed (flash)
        var = (x.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        xf = xf - xf.mean(-1, keepdims=True)
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    if cfg.norm == "layernorm":
        y = y + p["bias"]
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps=1e-6):
    """qk-norm: RMS-normalize the last (head) dim."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_angles(positions, head_dim, theta):
    """positions (..., S) int -> angles (..., S, head_dim//2) fp32."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions[..., None].astype(jnp.float32) * inv


def mrope_angles(positions3, head_dim, theta, sections):
    """M-RoPE (Qwen2-VL): positions3 (3, B, S); sections split head_dim//2."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions3[..., None].astype(jnp.float32) * inv  # (3, B, S, half)
    parts, off = [], 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., off : off + sec])
        off += sec
    return jnp.concatenate(parts, axis=-1)  # (B, S, half)


def apply_rope(x, angles):
    """x (B, S, H, Dh); angles (B, S, Dh//2). Half-split (NeoX) convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(seq, d_model):
    """Whisper-style fixed sinusoidal embeddings (seq, d_model)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention


def padded_heads(cfg, tp: int) -> int:
    """Q heads padded up to a TP multiple so attention always shards.

    §Perf (llama4: 40 heads on TP=16): non-divisible head counts previously
    fell back to *replicated* attention — 16× wasted compute, catastrophic at
    32k ctx (useful-FLOP ratio 0.12).  Padding to 48 costs 20% extra head
    compute but shards 16-way; pad heads are masked out after attention
    (zero contribution regardless of init), preserving the architecture.
    Padding only engages when it pays: pad/real ≤ 1.5 (whisper's 6 heads on
    TP=16 would pad 2.7× — it stays replicated instead).
    """
    hq = cfg.n_heads
    if hq % tp == 0:
        return hq
    pad = -(-hq // tp) * tp
    return pad if pad <= hq * 1.5 and pad % cfg.n_kv_heads == 0 else hq


def attn_specs(cfg, tp: int, dtype=None):
    """QKV/out projections. Heads shard over 'model' (padded if needed)."""
    dtype = dtype or cfg.params_dtype
    d, hkv, dh = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    hq = padded_heads(cfg, tp)
    hq_ax = "model" if hq % tp == 0 else None
    hkv_ax = "model" if hkv % tp == 0 else None
    s = {
        "wq": ParamSpec((d, hq, dh), dtype, P(None, hq_ax, None)),
        "wk": ParamSpec((d, hkv, dh), dtype, P(None, hkv_ax, None)),
        "wv": ParamSpec((d, hkv, dh), dtype, P(None, hkv_ax, None)),
        "wo": ParamSpec((hq, dh, d), dtype, P(hq_ax, None, None)),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((dh,), jnp.float32, P(), init="ones")
        s["k_norm"] = ParamSpec((dh,), jnp.float32, P(), init="ones")
    return s


def mask_pad_heads(cfg, o):
    """Zero the padded heads' attention output (B, S, Hpad, Dh)."""
    hpad = o.shape[2]
    if hpad == cfg.n_heads:
        return o
    mask = (jnp.arange(hpad) < cfg.n_heads).astype(o.dtype)
    return o * mask[None, None, :, None]


def qkv_project(cfg, p, x, policy: DTypePolicy, angles=None, x_kv=None):
    """Returns q (B,Sq,Hq,Dh), k/v (B,Skv,Hq,Dh) — kv already expanded to Hq heads."""
    cdt = policy.compute
    xq = x.astype(cdt)
    xkv = (x if x_kv is None else x_kv).astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    if angles is not None:
        q_ang, k_ang = angles if isinstance(angles, tuple) else (angles, angles)
        q = apply_rope(q, q_ang)
        k = apply_rope(k, k_ang)
    return q, k, v


def expand_kv(k, n_heads):
    """(B,S,Hkv,Dh) -> (B,S,Hq,Dh) by repeating each kv head G times."""
    g = n_heads // k.shape[2]
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def attn_out(p, o, policy):
    return jnp.einsum("bshk,hkd->bsd", o.astype(policy.compute), p["wo"].astype(policy.compute))


def dense_attention(q, k, v, *, causal, q_offset=0, logit_dtype=jnp.float32):
    """Reference/dense path (train_4k, decode, encoder). q (B,Sq,H,Dh), k/v (B,Skv,H,Dh)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(logit_dtype) * scale
    if causal:
        qpos = jnp.arange(q.shape[1])[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def decode_attention(q, k_cache, v_cache, length):
    """Single-step decode vs a (possibly longer-than-`length`) cache.

    q (B,1,H,Dh); caches (B,Smax,H,Dh); positions >= length are masked out.
    Runs fine with the cache sequence axis sharded (split-KV decoding: XLA
    inserts the partial-softmax collectives).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
    mask = jnp.arange(k_cache.shape[1])[None, None, None, :] < length
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v_cache)


def _flash_pairs(sq, sk, cq, ck):
    pairs = [(i, j) for i in range(sq // cq) for j in range((i + 1) * cq // ck)]
    return (
        jnp.array([p[0] for p in pairs], jnp.int32),
        jnp.array([p[1] for p in pairs], jnp.int32),
    )


def _flash_fwd_core(q, k, v, cq, ck):
    """Triangular chunk-pair scan. Returns (o fp32, lse fp32 (B,H,Sq))."""
    b, sq, h, dh = q.shape
    nq = sq // cq
    scale = 1.0 / math.sqrt(dh)
    pi, pj = _flash_pairs(sq, k.shape[1], cq, ck)
    acc0 = jnp.zeros((nq, b, h, cq, dh), jnp.float32)
    m0 = jnp.full((nq, b, h, cq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, b, h, cq), jnp.float32)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij
        qc = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32) * scale
        qpos = i * cq + jnp.arange(cq)[:, None]
        kpos = j * ck + jnp.arange(ck)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_old = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(m_old, s.max(-1))
        alpha = jnp.exp(m_old - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l_old * alpha + pexp.sum(-1)
        a_new = a_old * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", pexp, vc.astype(jnp.float32)
        )
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (pi, pj))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, sq, dh)
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))
    lse = jnp.moveaxis(lse, 0, 2).reshape(b, h, sq)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_train(q, k, v, chunk_q=512, chunk_k=512):
    """Causal flash attention with a flash *backward* (custom VJP).

    The dense-masked train path materializes (B,H,S,S) fp32 scores in HBM —
    the dominant roofline term of every attention arch's train_4k cell
    (EXPERIMENTS.md §Perf hillclimb #1).  This path streams (cq, ck) tiles:
    forward saves only (o, lse); backward re-computes per-tile scores and
    accumulates dq/dk/dv — O(S·D) memory, ideal-causal FLOPs.
    """
    o, _ = _flash_fwd_core(q, k, v, min(chunk_q, q.shape[1]), min(chunk_k, k.shape[1]))
    b, sq, h, dh = q.shape
    return jnp.moveaxis(o, 1, 2).astype(q.dtype)


def _flash_fwd(q, k, v, chunk_q, chunk_k):
    cq, ck = min(chunk_q, q.shape[1]), min(chunk_k, k.shape[1])
    o, lse = _flash_fwd_core(q, k, v, cq, ck)
    out = jnp.moveaxis(o, 1, 2).astype(q.dtype)  # (B,S,H,D)
    return out, (q, k, v, o, lse)


def _flash_bwd(chunk_q, chunk_k, res, do):
    q, k, v, o, lse = res  # o (B,H,S,D) fp32, lse (B,H,S)
    b, sq, h, dh = q.shape
    cq, ck = min(chunk_q, sq), min(chunk_k, k.shape[1])
    scale = 1.0 / math.sqrt(dh)
    do_f = jnp.moveaxis(do.astype(jnp.float32), 1, 2)  # (B,H,S,D)
    delta = (do_f * o).sum(-1)  # (B,H,S)
    pi, pj = _flash_pairs(sq, k.shape[1], cq, ck)
    dq0 = jnp.zeros(q.shape, jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)

    def body(carry, ij):
        dq, dk, dv = carry
        i, j = ij
        qc = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        lse_c = jax.lax.dynamic_slice_in_dim(lse, i * cq, cq, axis=2)
        dlt_c = jax.lax.dynamic_slice_in_dim(delta, i * cq, cq, axis=2)
        do_c = jax.lax.dynamic_slice_in_dim(do_f, i * cq, cq, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32) * scale
        qpos = i * cq + jnp.arange(cq)[:, None]
        kpos = j * ck + jnp.arange(ck)[None, :]
        p = jnp.where(qpos >= kpos, jnp.exp(s - lse_c[..., None]), 0.0)
        dv_c = jnp.einsum("bhqk,bhqd->bkhd", p, do_c)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do_c, vc.astype(jnp.float32))
        ds = p * (dp - dlt_c[..., None]) * scale
        dq_c = jnp.einsum("bhqk,bkhd->bqhd", ds, kc.astype(jnp.float32))
        dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qc.astype(jnp.float32))
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * cq, cq, 1) + dq_c, i * cq, 1
        )
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * ck, ck, 1) + dk_c, j * ck, 1
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * ck, ck, 1) + dv_c, j * ck, 1
        )
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), (pi, pj))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_train.defvjp(_flash_fwd, _flash_bwd)


def flash_prefill_attention(q, k, v, *, chunk_q=512, chunk_k=512):
    """Causal chunked-flash attention for long prefill (no grad path needed).

    Triangular (i, j<=i) chunk-pair scan: FLOPs = ideal causal cost (only the
    lower-triangular chunk grid is visited), memory = O(chunk² + output).
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    cq, ck = min(chunk_q, sq), min(chunk_k, sk)
    assert sq % cq == 0 and sk % ck == 0
    nq = sq // cq
    scale = 1.0 / math.sqrt(dh)
    pairs = [(i, j) for i in range(nq) for j in range((i + 1) * cq // ck)]
    pi = jnp.array([p[0] for p in pairs], jnp.int32)
    pj = jnp.array([p[1] for p in pairs], jnp.int32)

    acc0 = jnp.zeros((nq, b, h, cq, dh), jnp.float32)
    m0 = jnp.full((nq, b, h, cq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, b, h, cq), jnp.float32)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij
        qc = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32) * scale
        qpos = i * cq + jnp.arange(cq)[:, None]
        kpos = j * ck + jnp.arange(ck)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_old = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_blk = s.max(-1)
        m_new = jnp.maximum(m_old, m_blk)
        alpha = jnp.exp(m_old - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l_old * alpha + pexp.sum(-1)
        a_new = a_old * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", pexp, vc.astype(jnp.float32)
        )
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (pi, pj))
    out = acc / jnp.maximum(l[..., None], 1e-30)  # (nq, b, h, cq, dh)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP


def mlp_specs(cfg, tp: int, d_ff=None, dtype=None, fsdp=False):
    dtype = dtype or cfg.params_dtype
    d, f = cfg.d_model, d_ff or cfg.d_ff
    in_sp = P("data" if fsdp else None, "model")
    out_sp = P("model", "data" if fsdp else None)
    s = {
        "w_in": ParamSpec((d, f), dtype, in_sp),
        "w_out": ParamSpec((f, d), dtype, out_sp),
    }
    if cfg.act == "swiglu":
        s["w_gate"] = ParamSpec((d, f), dtype, in_sp)
    return s


def apply_mlp(cfg, p, x, policy: DTypePolicy):
    cdt = policy.compute
    xc = x.astype(cdt)
    h = xc @ p["w_in"].astype(cdt)
    if cfg.act == "swiglu":
        h = jax.nn.silu(xc @ p["w_gate"].astype(cdt)) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_out"].astype(cdt)


# ---------------------------------------------------------------------------
# embeddings / logits


def embed_specs(cfg, tp: int):
    vocab_ax = "model" if cfg.vocab_size % tp == 0 else None
    return {
        "table": ParamSpec(
            (cfg.vocab_size, cfg.d_model), cfg.params_dtype, P(vocab_ax, None), init="small"
        )
    }


def embed(p, tokens, policy):
    return jnp.take(p["table"], tokens, axis=0).astype(policy.compute)


def logits_specs(cfg, tp: int):
    if cfg.tie_embeddings:
        return {}
    vocab_ax = "model" if cfg.vocab_size % tp == 0 else None
    return {"w": ParamSpec((cfg.d_model, cfg.vocab_size), cfg.params_dtype, P(None, vocab_ax))}


def logits(cfg, p_lm, p_embed, x, policy):
    """Returns logits sharded over 'model' on the vocab axis (never gathered)."""
    cdt = policy.compute
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x.astype(cdt), p_embed["table"].astype(cdt))
    return x.astype(cdt) @ p_lm["w"].astype(cdt)


def cross_entropy(lg, targets, mask=None):
    """Mean next-token CE from (B,S,V) logits (V may be sharded) in fp32."""
    lg = lg.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
