"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings ``enc_feats (B, S_enc, d_model)``.  The encoder
adds fixed sinusoidal positions and runs bidirectional attention; the decoder
uses learned positions, causal self-attention and cross-attention to the
encoded memory.  Cross K/V are computed once (at prefill) and cached.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import ParamSpec, with_sharding
from repro.models import layers as L
from repro.models.transformer import stack_specs

MAX_DEC_POS = 32768  # decode_32k needs 32k learned decoder positions


def _enc_layer_specs(cfg, tp):
    return {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg, tp),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg, tp),
    }


def _dec_layer_specs(cfg, tp):
    return {
        "ln1": L.norm_specs(cfg),
        "attn": L.attn_specs(cfg, tp),
        "ln_x": L.norm_specs(cfg),
        "xattn": L.attn_specs(cfg, tp),
        "ln2": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg, tp),
    }


def encdec_specs(cfg, tp: int = 16, fsdp: bool = False):
    return {
        "embed": L.embed_specs(cfg, tp),
        "dec_pos": ParamSpec((MAX_DEC_POS, cfg.d_model), cfg.params_dtype, P(), init="small"),
        "enc_layers": stack_specs(_enc_layer_specs(cfg, tp), cfg.n_enc_layers),
        "enc_norm": L.norm_specs(cfg),
        "dec_layers": stack_specs(_dec_layer_specs(cfg, tp), cfg.n_layers),
        "final_norm": L.norm_specs(cfg),
    }


def _bidir_attn(cfg, p, x, policy, x_kv=None):
    q, k, v = L.qkv_project(cfg, p, x, policy, angles=None, x_kv=x_kv)
    o = L.dense_attention(
        q, L.expand_kv(k, cfg.n_heads), L.expand_kv(v, cfg.n_heads), causal=False
    )
    return L.attn_out(p, o, policy)


def encode(cfg, params, enc_feats, policy, mesh=None):
    """enc_feats (B, S_enc, d) -> memory (B, S_enc, d).

    With head counts below the TP degree (whisper: 6 < 16), the encoder is
    sequence-sharded over 'model' instead: the bidirectional attention
    contracts across the sharded axis (GSPMD inserts the partial-softmax
    collectives) and the 32k×32k score matrices split 16 ways.
    """
    seq_ax = "model" if (cfg.n_heads % 16 and enc_feats.shape[1] % 16 == 0) else None
    h = enc_feats.astype(policy.compute)
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)[None]
    h = with_sharding(h, mesh, P(L.DATA_AXES, seq_ax, None))

    def body(x, lp):
        a = _bidir_attn(cfg, lp["attn"], L.apply_norm(cfg, lp["ln1"], x), policy)
        x = x + a
        x = x + L.apply_mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], x), policy)
        return with_sharding(x, mesh, P(L.DATA_AXES, seq_ax, None)), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_norm"], h)


def cross_kv(cfg, params, memory, policy):
    """Precompute per-decoder-layer cross K/V from the encoder memory.

    Returns (k, v) stacked (L_dec, B, S_enc, Hkv, Dh) — part of the cache.
    """
    cdt = policy.compute

    def body(_, lp):
        k = jnp.einsum("bsd,dhk->bshk", memory.astype(cdt), lp["xattn"]["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", memory.astype(cdt), lp["xattn"]["wv"].astype(cdt))
        return None, (k, v)

    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv


def _dec_layer(cfg, lp, x, policy, *, mode, cache, xkv, pos):
    from repro.models.transformer import attn_apply, _grouped_decode_attention

    a, self_c = attn_apply(
        cfg, lp, L.apply_norm(cfg, lp["ln1"], x), policy,
        mode=mode, angles=None, cache=cache, pos=pos,
    )
    x = x + a
    # cross attention against fixed memory K/V
    xq = L.apply_norm(cfg, lp["ln_x"], x)
    q = jnp.einsum(
        "bsd,dhk->bshk", xq.astype(policy.compute), lp["xattn"]["wq"].astype(policy.compute)
    )
    xk, xv = xkv
    if q.shape[1] == 1:
        o = _grouped_decode_attention(q, xk, xv, xk.shape[1])
    else:
        o = L.dense_attention(
            q, L.expand_kv(xk, cfg.n_heads), L.expand_kv(xv, cfg.n_heads), causal=False
        )
    x = x + L.attn_out(lp["xattn"], o, policy)
    x = x + L.apply_mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], x), policy)
    return x, self_c


def decode_forward(cfg, params, tokens, policy, *, mode, cache=None, xkv=None, pos=0, mesh=None):
    """Decoder stack. tokens (B, S_dec); mode train|prefill|decode.

    cache: stacked self-attn (k, v) for decode; xkv: stacked cross (k, v).
    """
    b, s = tokens.shape
    h = L.embed(params["embed"], tokens, policy) * math.sqrt(cfg.d_model)
    start = pos if mode == "decode" else 0
    h = h + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], start, s, axis=0
    ).astype(h.dtype)[None]
    h = with_sharding(h, mesh, P(L.DATA_AXES, None, None))

    def body(x, xs):
        lp, c, kv = xs
        x, c_out = _dec_layer(cfg, lp, x, policy, mode=mode, cache=c, xkv=kv, pos=pos)
        return with_sharding(x, mesh, P(L.DATA_AXES, None, None)), c_out

    h, c_out = jax.lax.scan(body, h, (params["dec_layers"], cache, xkv))
    h = L.apply_norm(cfg, params["final_norm"], h)
    return h, (c_out if mode != "train" else None)


def encdec_loss_forward(cfg, params, batch, policy, mesh=None):
    """Training forward: returns final decoder hidden states."""
    memory = encode(cfg, params, batch["enc_feats"], policy, mesh=mesh)
    xkv = cross_kv(cfg, params, memory, policy)
    h, _ = decode_forward(
        cfg, params, batch["tokens"], policy, mode="train", cache=None, xkv=xkv, mesh=mesh
    )
    return h
