"""Shared utilities: dtype policy, tree helpers, deterministic RNG, spec trees.

Everything in the framework is pure-functional: parameters, optimizer states,
simulation states are pytrees (nested dicts) of jnp arrays.  Alongside every
param tree we carry a *spec tree* of identical structure whose leaves are
``ParamSpec`` (shape, dtype, PartitionSpec) — the single source of truth used
by init, checkpointing and the dry-run's ``in_shardings``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape/dtype/sharding descriptor for one parameter leaf."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    pspec: P = P()
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float | None = None  # override fan-in scale

    def shape_dtype(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn: Callable[[ParamSpec], Any], tree: Pytree) -> Pytree:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def shape_dtypes(tree: Pytree) -> Pytree:
    """Spec tree -> ShapeDtypeStruct tree (for .lower())."""
    return spec_map(lambda s: s.shape_dtype(), tree)


def filter_pspec(pspec: P, mesh: Mesh) -> P:
    """Drop axis names not present in the mesh (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)

    def f(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(f(e) for e in pspec))


def shardings(tree: Pytree, mesh: Mesh) -> Pytree:
    """Spec tree -> NamedSharding tree (for in_shardings)."""
    return spec_map(lambda s: NamedSharding(mesh, filter_pspec(s.pspec, mesh)), tree)


def named(mesh: Mesh, pspec: P) -> NamedSharding:
    return NamedSharding(mesh, filter_pspec(pspec, mesh))


def pspecs(tree: Pytree) -> Pytree:
    return spec_map(lambda s: s.pspec, tree)


def param_count(tree: Pytree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(tree, is_leaf=is_spec))


def param_bytes(tree: Pytree) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(tree, is_leaf=is_spec)
    )


def _init_leaf(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
    if s.init == "embed":
        scale = 1.0
    elif s.init == "small":
        scale = 0.02
    else:
        scale = s.scale if s.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)


def init_params(key, spec_tree: Pytree) -> Pytree:
    """Deterministically initialize a param tree from its spec tree."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(k, s) for k, s in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# dtype policy


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    params: Any = jnp.float32  # storage dtype of parameters
    compute: Any = jnp.bfloat16  # matmul dtype
    accum: Any = jnp.float32  # softmax / reductions / loss


def cast_compute(policy: DTypePolicy, tree: Pytree) -> Pytree:
    return jax.tree.map(
        lambda x: x.astype(policy.compute) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


# ---------------------------------------------------------------------------
# misc


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def tree_bytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def with_sharding(x, mesh: Mesh | None, pspec: P):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, filter_pspec(pspec, mesh)))


def take_layer(stacked: Pytree, i):
    """Index layer i out of a (L, ...)-stacked param tree."""
    return jax.tree.map(lambda x: x[i], stacked)
