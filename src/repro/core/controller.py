"""Simulation controller: quantum stepping + time-decoupled synchronization
(paper §IV, Fig. 2/3) with four execution backends.

Per round (= the paper's ``exec`` + ``sync``):

  limit_i = min_{j≠i} (time_j + latency[j, i])      # decoupling bound
  states'_i, outbox_i = segment_step(states_i, pending_i, limit_i)
  pending' = merge(pending, route(outboxes))        # sync

Backends for the ``exec`` phase (DESIGN.md §2):
  sequential — one host thread steps segments one after another: the
               conventional SystemC baseline ("sq");
  vmap       — segments stacked and stepped as one vectorized program: the
               single-device parallel backend ("pll" on a 1-core host);
  threads    — one host thread per segment (the paper's literal mechanism;
               only wins on multi-core hosts);
  shard_map  — one mesh device per segment; routing becomes an all-gather
               over the ``segment`` axis.  This is the production backend
               the multi-pod dry-run lowers.

All four produce bit-identical simulation results (property-tested): time
decoupling changes wall-clock interleaving, never simulated semantics.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time as _time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.vp import platform as pf


_FN_CACHE: dict = {}  # (cfg, quantum, kind) -> compiled fns; benchmarks
                      # rebuild controllers per workload with identical shapes


@dataclasses.dataclass
class Controller:
    cfg: pf.VPConfig
    states: object  # stacked (S, ...) pytree
    pending: object  # stacked (S, IN_CAP)
    backend: str = "vmap"
    quantum: int = 10_000
    mesh: object = None  # shard_map backend only
    rounds_run: int = 0

    def __post_init__(self):
        # own the state: round fns donate their inputs, so the caller's
        # arrays must not be shared with this controller
        self.states = jax.tree.map(jnp.copy, self.states)
        self.pending = jax.tree.map(jnp.copy, self.pending)
        self.lat = self.cfg.latency_matrix()
        # sequential/threads keep per-segment state as persistent lists —
        # the honest "sq" baseline must not pay per-round slice/stack of the
        # 4 MB DRAM image (that would inflate the parallel speedup)
        self._list_mode = self.backend in ("sequential", "threads")
        if self._list_mode:
            s = self.cfg.n_segments
            take = lambda t, i: jax.tree.map(lambda x: x[i], t)
            self._states_l = [take(self.states, i) for i in range(s)]
            self._pending_l = [take(self.pending, i) for i in range(s)]
        step = pf.make_segment_step(self.cfg, self.quantum)
        s = self.cfg.n_segments
        big = jnp.int32(2**30)

        def limits(times):
            # limit_i = min_{j != i}(t_j + lat[j, i]); single segment: t + q
            tl = times[:, None] + self.lat  # (src, dst)
            eye = jnp.eye(s, dtype=bool)
            tl = jnp.where(eye, big, tl)
            lim = tl.min(axis=0)
            if s == 1:
                lim = times + self.quantum
            return lim

        def vmap_round(states, pending):
            lim = limits(states["time"])
            states, outboxes, pending = jax.vmap(step)(states, pending, lim)
            fresh = ch.route(outboxes, self.lat, pf.IN_CAP)
            pending = jax.vmap(ch.merge_pending)(pending, fresh)
            return states, pending

        key = (self.cfg, self.quantum, s)
        if key not in _FN_CACHE:
            _FN_CACHE[key] = {
                "vmap_round": jax.jit(vmap_round, donate_argnums=(0, 1)),
                "step_one": jax.jit(step),
                "limits": jax.jit(limits),
                "route": jax.jit(lambda outboxes: ch.route(outboxes, self.lat, pf.IN_CAP)),
                "merge_one": jax.jit(ch.merge_pending, donate_argnums=(0,)),
            }
        fns = _FN_CACHE[key]
        self._vmap_round = fns["vmap_round"]
        self._step_one = fns["step_one"]
        self._limits = fns["limits"]
        self._route = fns["route"]
        self._merge_one = fns["merge_one"]

        if self.backend == "shard_map":
            from jax.sharding import PartitionSpec as P

            assert self.mesh is not None, "shard_map backend needs a mesh"

            def shard_round(states, pending):
                def body(states1, pending1):
                    # leading segment axis is mapped: local shapes (1, ...)
                    my = jax.tree.map(lambda x: x[0], states1)
                    pen = jax.tree.map(lambda x: x[0], pending1)
                    seg_times = jax.lax.all_gather(my["time"], "segment")
                    i = jax.lax.axis_index("segment")
                    tl = seg_times + self.lat[:, i]
                    tl = jnp.where(jnp.arange(s) == i, big, tl)
                    lim = tl.min()
                    st, outbox, pen = step(my, pen, lim)
                    all_out = jax.lax.all_gather(outbox, "segment")  # (S, cap)
                    t_avail = all_out["t_emit"] + self.lat[
                        jnp.repeat(jnp.arange(s), pf.OUT_CAP).reshape(s, pf.OUT_CAP), i
                    ]
                    flat_valid = (all_out["valid"] & (all_out["dst"] == i)).reshape(-1)
                    rank = jnp.cumsum(flat_valid.astype(jnp.int32)) - 1
                    # dead lanes scatter out-of-bounds and drop (channel.py's
                    # "never write a dead slot" rule) so an exactly-full
                    # inbox keeps its last message instead of racing it
                    # against thousands of zero writes to the same slot
                    pos = jnp.where(flat_valid, jnp.clip(rank, 0, pf.IN_CAP - 1), pf.IN_CAP)
                    fresh = ch.empty_pending(pf.IN_CAP)
                    for f, src in (("kind", all_out["kind"]), ("addr", all_out["addr"]),
                                   ("data", all_out["data"]), ("t_avail", t_avail)):
                        fresh[f] = fresh[f].at[pos].set(src.reshape(-1), mode="drop")
                    fresh["valid"] = fresh["valid"].at[pos].set(flat_valid, mode="drop")
                    fresh["count"] = flat_valid.sum().astype(jnp.int32)
                    pen = ch.merge_pending(pen, fresh)
                    exp = lambda t: jax.tree.map(lambda x: x[None], t)
                    return exp(st), exp(pen)

                from repro.compat import shard_map

                return shard_map(
                    body,
                    mesh=self.mesh,
                    in_specs=(P("segment"), P("segment")),
                    out_specs=(P("segment"), P("segment")),
                )(states, pending)

            self._shard_round = jax.jit(shard_round, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def round(self):
        s = self.cfg.n_segments
        if self.backend == "vmap":
            self.states, self.pending = self._vmap_round(self.states, self.pending)
        elif self.backend == "shard_map":
            self.states, self.pending = self._shard_round(self.states, self.pending)
        elif self._list_mode:
            times = jnp.stack([st["time"] for st in self._states_l])
            lim = self._limits(times)

            def one(i):
                return self._step_one(self._states_l[i], self._pending_l[i], lim[i])

            if self.backend == "sequential":
                results = [one(i) for i in range(s)]
            else:
                with cf.ThreadPoolExecutor(max_workers=s) as ex:
                    results = list(ex.map(one, range(s)))
            self._states_l = [r[0] for r in results]
            stack = lambda xs: jax.tree.map(lambda *v: jnp.stack(v), *xs)
            outboxes = stack([r[1] for r in results])  # ~100 KB each: cheap
            fresh = self._route(outboxes)
            take = lambda t, i: jax.tree.map(lambda x: x[i], t)
            self._pending_l = [
                self._merge_one(r[2], take(fresh, i)) for i, r in enumerate(results)
            ]
        else:
            raise ValueError(self.backend)
        self.rounds_run += 1

    def _stacked(self):
        if self._list_mode:
            return jax.tree.map(lambda *v: jnp.stack(v), *self._states_l)
        return self.states

    def _pending_stacked(self):
        if self._list_mode:
            return jax.tree.map(lambda *v: jnp.stack(v), *self._pending_l)
        return self.pending

    def _check_overflow(self, pending=None, states=None):
        # loud overflow sentinels: merge_pending and the segment step keep
        # sticky high-water marks of the capacity they needed; past-cap
        # scatters clip onto the last slot (documented-nondeterministic
        # overwrite), so any watermark beyond capacity means messages were
        # silently corrupted at some point — even if the box drained since
        pending = self._pending_stacked() if pending is None else pending
        watermark = np.asarray(pending["max_count"])
        if (watermark > pf.IN_CAP).any():
            raise RuntimeError(
                f"pending inbox overflow (watermark {watermark.tolist()} > "
                f"{pf.IN_CAP}); raise IN_CAP or thin the workload's traffic"
            )
        states = self._stacked() if states is None else states
        out_peak = np.asarray(states["stats"]["outbox_peak"])
        if (out_peak > pf.OUT_CAP).any():
            raise RuntimeError(
                f"outbox overflow (peak {out_peak.tolist()} > {pf.OUT_CAP}); "
                "raise OUT_CAP or thin the workload's traffic"
            )

    def done(self) -> bool:
        states = self._stacked()
        pending = self._pending_stacked()
        self._check_overflow(pending, states)
        cpus = states["cpu"]
        active_cpu = bool(jnp.any(cpus["present"] & ~cpus["halted"]))
        # a unit that is merely armed (CONFIG'd, state IN, no pending input)
        # is not forward progress; only an in-flight OP blocks termination
        busy_cim = bool(jnp.any(states["cims"]["state"] == 2))
        # a spike-mode unit is busy while it has accumulated-but-unintegrated
        # spikes OR an active neuron already at threshold (possible when a
        # runtime CIM_REG_MODE write lowers thresh under a charged membrane):
        # either will change observable state at the unit's next tick.  With
        # an empty buffer and everyone subthreshold, leak alone can never
        # cross threshold (leak >= 0, reset-to-zero), so idling is final.
        # Units that never tick (tick_period == 0, e.g. flipped to spike mode
        # at runtime without build-time wiring) can never drain — not busy.
        from repro.vp import isa

        cims = states["cims"]
        ticking = (cims["mode"] == isa.CIM_MODE_SPIKE) & (cims["tick_period"] > 0)
        pending_in = (cims["in_buf"] != 0).any(-1)
        due = ((cims["v"] >= cims["thresh"][..., None]) & (cims["refrac"] == 0)).any(-1)
        busy_snn = bool(jnp.any(ticking & (pending_in | due)))
        msgs = bool(jnp.any(pending["valid"]))
        return not (active_cpu or busy_cim or busy_snn or msgs)

    def run(self, max_rounds: int = 10_000, check_every: int = 4):
        """Run to completion; returns (rounds, host_seconds)."""
        t0 = _time.perf_counter()
        for r in range(max_rounds):
            self.round()
            if (r + 1) % check_every == 0 and self.done():
                break
        else:
            self._check_overflow()  # done() may never have seen the last rounds
        jax.block_until_ready(self._states_l if self._list_mode else self.states)
        return self.rounds_run, _time.perf_counter() - t0

    # ------------------------------------------------------------------
    def result_states(self):
        """Stacked (S, ...) states regardless of backend."""
        return self._stacked()

    def sim_time(self):
        return np.asarray(self._stacked()["time"])

    def stats(self):
        states = self._stacked()
        st = states["stats"]
        return {
            "instructions": np.asarray(st["instrs"]),
            "messages": np.asarray(st["msgs"]),
            "txn_histogram": np.asarray(st["txn_hist"]).sum(0),
            "cache": {
                "d_hits": np.asarray(states["dcache"]["hits"]),
                "d_misses": np.asarray(states["dcache"]["misses"]),
            },
            "dram": {
                "reads": np.asarray(states["dram"]["reads"]),
                "writes": np.asarray(states["dram"]["writes"]),
            },
            "cim_ops": np.asarray(states["cims"]["ops"]),
            "snn": {
                "spikes": np.asarray(states["cims"]["spikes_total"]),
                "ticks": np.asarray(states["cims"]["ticks"]),
            },
        }
