"""Simulation controller: quantum stepping + time-decoupled synchronization
(paper §IV, Fig. 2/3) with four execution backends.

Per round (= the paper's ``exec`` + ``sync``):

  limit_i = min_{j≠i} (time_j + latency[j, i])      # decoupling bound
  states'_i, outbox_i = segment_step(states_i, pending_i, limit_i)
  pending' = merge(pending, route(outboxes))        # sync

Backends for the ``exec`` phase (DESIGN.md §2):
  sequential — one host thread steps segments one after another: the
               conventional SystemC baseline ("sq");
  vmap       — segments stacked and stepped as one vectorized program: the
               single-device parallel backend ("pll" on a 1-core host);
  threads    — one host thread per segment (the paper's literal mechanism;
               only wins on multi-core hosts);
  shard_map  — one mesh device per segment; routing becomes an all-gather
               over the ``segment`` axis.  This is the production backend
               the multi-pod dry-run lowers.

All four produce bit-identical simulation results (property-tested): time
decoupling changes wall-clock interleaving, never simulated semantics.

The stacked backends (``vmap``/``shard_map``) additionally run the round
loop itself device-resident: ``run()`` dispatches a fused *megastep* — one
jitted ``jax.lax.while_loop`` that executes up to ``rounds_per_dispatch``
exec+sync rounds per host dispatch, evaluating the termination predicate
and the sticky overflow watermarks on-device (``platform.termination_flags``)
so the host syncs one tiny scalar tuple per dispatch instead of four
``bool(jnp.any(...))`` round-trips per ``check_every`` rounds.  Results,
round counts, and overflow errors are bit-identical to per-round execution
(``fused=False``).  ``sequential``/``threads`` keep their honest host-side
per-round loop (they *are* the host-scheduling baselines) but share the
fused single-sync done-reducer.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time as _time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.vp import platform as pf


_FN_CACHE: dict = {}  # (cfg, quantum, kind) -> compiled fns; benchmarks
                      # rebuild controllers per workload with identical shapes


@dataclasses.dataclass
class Controller:
    cfg: pf.VPConfig
    states: object  # stacked (S, ...) pytree
    pending: object  # stacked (S, IN_CAP)
    backend: str = "vmap"
    quantum: int = 10_000
    mesh: object = None  # shard_map backend only
    rounds_run: int = 0

    def __post_init__(self):
        # own the state: round fns donate their inputs, so the caller's
        # arrays must not be shared with this controller
        self.states = jax.tree.map(jnp.copy, self.states)
        self.pending = jax.tree.map(jnp.copy, self.pending)
        # the CPU-free fast path (VPConfig.has_cpu=False: no slot scan, no
        # MMIO inbox handling, no dense completion) is only valid while
        # nothing but AER spikes can circulate.  The builder guarantees that
        # for its own wiring, but callers may hand-inject MMIO/DMA messages
        # into the initial pending box — detect that once and fall back to
        # the full step (one host check at construction, never per round)
        if not self.cfg.has_cpu:
            injected = np.asarray(
                self.pending["valid"] & (self.pending["kind"] != ch.MSG_SPIKE)
            )
            if injected.any():
                self.cfg = dataclasses.replace(self.cfg, has_cpu=True)
        self.lat = self.cfg.latency_matrix()
        # sequential/threads keep per-segment state as persistent lists —
        # the honest "sq" baseline must not pay per-round slice/stack of the
        # 4 MB DRAM image (that would inflate the parallel speedup)
        self._list_mode = self.backend in ("sequential", "threads")
        if self._list_mode:
            s = self.cfg.n_segments
            take = lambda t, i: jax.tree.map(lambda x: x[i], t)
            self._states_l = [take(self.states, i) for i in range(s)]
            self._pending_l = [take(self.pending, i) for i in range(s)]
        # threads backend: one persistent pool for the controller's life —
        # creating and tearing down a ThreadPoolExecutor every round would
        # penalize the paper's literal parallel mechanism with pure host
        # overhead (thread spawn/join per quantum)
        self._pool = (
            cf.ThreadPoolExecutor(max_workers=self.cfg.n_segments,
                                  thread_name_prefix="vp-seg")
            if self.backend == "threads" else None
        )
        step = pf.make_segment_step(self.cfg, self.quantum)
        s = self.cfg.n_segments
        big = jnp.int32(2**30)
        # locals, NOT self.*, inside the jitted closures below: _FN_CACHE
        # outlives controllers, and a closure over `self` would pin the
        # first instance's entire copied state (MB of DRAM image per
        # segment) for process lifetime
        cfg = self.cfg
        lat = self.lat
        quantum = self.quantum

        def limits(times):
            # limit_i = min_{j != i}(t_j + lat[j, i]); single segment: t + q
            tl = times[:, None] + lat  # (src, dst)
            eye = jnp.eye(s, dtype=bool)
            tl = jnp.where(eye, big, tl)
            lim = tl.min(axis=0)
            if s == 1:
                lim = times + quantum
            return lim

        def vmap_round(states, pending):
            lim = limits(states["time"])
            states, outboxes, pending = jax.vmap(step)(states, pending, lim)
            fresh = ch.route(outboxes, lat, cfg.in_cap)
            pending = jax.vmap(ch.merge_pending)(pending, fresh)
            return states, pending

        def megaloop(round_fn):
            """Device-resident round loop: up to ``k`` rounds of ``round_fn``
            inside one ``lax.while_loop``, with the termination predicate and
            sticky overflow watermarks evaluated in traced code at the same
            points the host loop would (every ``check_every``-th round since
            ``run()`` started, ``r0`` rounds ago).  The host sees one scalar
            tuple per dispatch.  ``done`` means clean termination; ``over``
            means a watermark tripped at a check point — the host re-raises
            with the detailed message (the loop stops at the same round the
            per-round path would, so the message is identical too)."""

            def mega(states, pending, r0, k, check_every):
                def cond(carry):
                    _, _, i, done, over = carry
                    return ~(done | over) & (i < k)

                def body(carry):
                    st, pen, i, _, _ = carry
                    st, pen = round_fn(st, pen)
                    i = i + 1
                    at_check = ((r0 + i) % check_every) == 0

                    def checked(_):
                        done, in_over, out_over, st_over, late = \
                            pf.termination_flags(
                                st, pen, cfg.in_cap, cfg.out_cap, cfg.store_log)
                        over = in_over | out_over | st_over | late
                        return done & ~over, over

                    # cond, not where: non-check rounds skip the reductions
                    done, over = jax.lax.cond(
                        at_check, checked,
                        lambda _: (jnp.array(False), jnp.array(False)), None)
                    return st, pen, i, done, over

                zero, false = jnp.int32(0), jnp.array(False)
                return jax.lax.while_loop(
                    cond, body, (states, pending, zero, false, false)
                )

            return mega

        key = (self.cfg, self.quantum, s)
        if key not in _FN_CACHE:
            _FN_CACHE[key] = {
                "vmap_round": jax.jit(vmap_round, donate_argnums=(0, 1)),
                "vmap_mega": jax.jit(megaloop(vmap_round), donate_argnums=(0, 1)),
                "flags": jax.jit(
                    lambda states, pending: jnp.stack(pf.termination_flags(
                        states, pending, cfg.in_cap, cfg.out_cap, cfg.store_log))
                ),
                "step_one": jax.jit(step),
                "limits": jax.jit(limits),
                "route": jax.jit(lambda outboxes: ch.route(outboxes, lat, cfg.in_cap)),
                "merge_one": jax.jit(ch.merge_pending, donate_argnums=(0,)),
            }
        fns = _FN_CACHE[key]
        self._vmap_round = fns["vmap_round"]
        self._vmap_mega = fns["vmap_mega"]
        self._flags_fn = fns["flags"]
        self._step_one = fns["step_one"]
        self._limits = fns["limits"]
        self._route = fns["route"]
        self._merge_one = fns["merge_one"]

        if self.backend == "shard_map":
            from jax.sharding import PartitionSpec as P

            assert self.mesh is not None, "shard_map backend needs a mesh"

            def shard_round(states, pending):
                def body(states1, pending1):
                    # leading segment axis is mapped: local shapes (1, ...)
                    my = jax.tree.map(lambda x: x[0], states1)
                    pen = jax.tree.map(lambda x: x[0], pending1)
                    seg_times = jax.lax.all_gather(my["time"], "segment")
                    i = jax.lax.axis_index("segment")
                    tl = seg_times + self.lat[:, i]
                    tl = jnp.where(jnp.arange(s) == i, big, tl)
                    lim = tl.min()
                    st, outbox, pen = step(my, pen, lim)
                    all_out = jax.lax.all_gather(outbox, "segment")  # (S, cap)
                    t_avail = all_out["t_emit"] + self.lat[
                        jnp.repeat(jnp.arange(s), self.cfg.out_cap).reshape(s, self.cfg.out_cap), i
                    ]
                    flat_valid = (all_out["valid"] & (all_out["dst"] == i)).reshape(-1)
                    rank = jnp.cumsum(flat_valid.astype(jnp.int32)) - 1
                    # dead lanes scatter out-of-bounds and drop (channel.py's
                    # "never write a dead slot" rule) so an exactly-full
                    # inbox keeps its last message instead of racing it
                    # against thousands of zero writes to the same slot
                    pos = jnp.where(flat_valid, jnp.clip(rank, 0, self.cfg.in_cap - 1), self.cfg.in_cap)
                    fresh = ch.empty_pending(self.cfg.in_cap)
                    for f, src in (("kind", all_out["kind"]), ("addr", all_out["addr"]),
                                   ("data", all_out["data"]), ("t_avail", t_avail)):
                        fresh[f] = fresh[f].at[pos].set(src.reshape(-1), mode="drop")
                    fresh["valid"] = fresh["valid"].at[pos].set(flat_valid, mode="drop")
                    fresh["count"] = flat_valid.sum().astype(jnp.int32)
                    pen = ch.merge_pending(pen, fresh)
                    exp = lambda t: jax.tree.map(lambda x: x[None], t)
                    return exp(st), exp(pen)

                from repro.compat import shard_map

                return shard_map(
                    body,
                    mesh=self.mesh,
                    in_specs=(P("segment"), P("segment")),
                    out_specs=(P("segment"), P("segment")),
                )(states, pending)

            self._shard_round = jax.jit(shard_round, donate_argnums=(0, 1))
            # mesh-dependent, so per-instance rather than in _FN_CACHE; the
            # while_loop wraps the shard_map call and the flags reduce over
            # the sharded carry (XLA inserts the all-reduce)
            self._shard_mega = jax.jit(megaloop(shard_round), donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def _require_open(self):
        if getattr(self, "_closed", False):
            raise RuntimeError(
                "Controller is closed: close() released its host resources "
                "(the threads backend's worker pool); build a new Controller "
                "to run again"
            )

    def round(self):
        self._require_open()
        s = self.cfg.n_segments
        if self.backend == "vmap":
            self.states, self.pending = self._vmap_round(self.states, self.pending)
        elif self.backend == "shard_map":
            self.states, self.pending = self._shard_round(self.states, self.pending)
        elif self._list_mode:
            times = jnp.stack([st["time"] for st in self._states_l])
            lim = self._limits(times)

            def one(i):
                return self._step_one(self._states_l[i], self._pending_l[i], lim[i])

            if self.backend == "sequential":
                results = [one(i) for i in range(s)]
            else:
                results = list(self._pool.map(one, range(s)))
            self._states_l = [r[0] for r in results]
            stack = lambda xs: jax.tree.map(lambda *v: jnp.stack(v), *xs)
            outboxes = stack([r[1] for r in results])  # ~100 KB each: cheap
            fresh = self._route(outboxes)
            take = lambda t, i: jax.tree.map(lambda x: x[i], t)
            self._pending_l = [
                self._merge_one(r[2], take(fresh, i)) for i, r in enumerate(results)
            ]
        else:
            raise ValueError(self.backend)
        self.rounds_run += 1

    def _stacked(self):
        if self._list_mode:
            return jax.tree.map(lambda *v: jnp.stack(v), *self._states_l)
        return self.states

    def _pending_stacked(self):
        if self._list_mode:
            return jax.tree.map(lambda *v: jnp.stack(v), *self._pending_l)
        return self.pending

    def _check_overflow(self, pending=None, states=None):
        # loud overflow sentinels: merge_pending and the segment step keep
        # sticky high-water marks of the capacity they needed; past-cap
        # messages are silently lost (bulk appends/merges truncate, single
        # appends clip onto the last slot), so any watermark beyond capacity
        # means messages were dropped at some point — even if the box
        # drained since
        pending = self._pending_stacked() if pending is None else pending
        watermark = np.asarray(pending["max_count"])
        if (watermark > self.cfg.in_cap).any():
            raise RuntimeError(
                f"pending inbox overflow (watermark {watermark.tolist()} > "
                f"{self.cfg.in_cap}); raise in_cap (builder kwarg) or thin "
                "the workload's traffic"
            )
        states = self._stacked() if states is None else states
        out_peak = np.asarray(states["stats"]["outbox_peak"])
        if (out_peak > self.cfg.out_cap).any():
            raise RuntimeError(
                f"outbox overflow (peak {out_peak.tolist()} > {self.cfg.out_cap}); "
                "raise out_cap (builder kwarg) or thin the workload's traffic"
            )
        store_peak = np.asarray(states["stats"]["store_peak"])
        if (store_peak > self.cfg.store_log).any():
            raise RuntimeError(
                f"DRAM store-log overflow (peak {store_peak.tolist()} > "
                f"{self.cfg.store_log} stores in one quantum); raise store_log "
                "(builder kwarg) or shrink the quantum"
            )
        mmio_late = np.asarray(states["stats"]["snn_mmio_late"])
        if (mmio_late > 0).any():
            raise RuntimeError(
                f"late SNN MMIO ops ({mmio_late.tolist()} per segment): a "
                "CIM_REG_SPIKE store executed at/after its target tick's grid "
                "time, or a CIM_REG_COUNTS readback was served after the unit "
                "ticked past the requested count — the result would depend on "
                "round timing, not the tick grid.  Issue the op earlier in "
                "the program, or raise tick_period (builder kwarg) so the "
                "injection window covers it"
            )

    def done(self) -> bool:
        """Termination check + loud overflow validation (one device sync).

        The predicate itself lives in traced code
        (``platform.termination_flags`` — see its docstring for the exact
        semantics: running CPUs, in-flight CIM OPs, drainable spike-mode
        work, pending spike-count readbacks, pending messages); here it is
        evaluated as one fused jitted call returning a single (5,) bool
        array — done + the inbox/outbox/store-log watermarks and the
        late-SNN-MMIO flag — instead of separate ``bool(jnp.any(...))``
        host round-trips.
        """
        d, in_over, out_over, store_over, mmio_late = np.asarray(
            self._flags_fn(self._stacked(), self._pending_stacked())
        )
        if in_over or out_over or store_over or mmio_late:
            self._check_overflow()  # raises with the detailed watermark message
        return bool(d)

    def block_until_ready(self):
        """Wait for this controller's device state to materialize.

        Public replacement for benchmarks reaching into ``_states_l`` /
        ``_list_mode``; returns self so warm-up reads chain."""
        if self._list_mode:
            jax.block_until_ready((self._states_l, self._pending_l))
        else:
            jax.block_until_ready((self.states, self.pending))
        return self

    def close(self):
        """Release host resources (the threads backend's persistent pool).

        Idempotent; a closed controller refuses to ``run``/``round`` with a
        clear error instead of dying inside the round machinery.  Reading
        results (``result_states``/``stats``/``done``) stays valid."""
        if getattr(self, "_pool", None) is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        self._closed = True

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def run(self, max_rounds: int = 10_000, check_every: int = 4,
            fused: bool | None = None, rounds_per_dispatch: int = 256):
        """Run to completion; returns (rounds, host_seconds).

        ``vmap``/``shard_map`` default to the device-resident megaloop
        (``fused=True``): up to ``rounds_per_dispatch`` rounds execute per
        host dispatch inside one jitted ``lax.while_loop`` that checks the
        termination predicate and overflow watermarks on-device at every
        ``check_every``-th round — bit-identical results, ``rounds_run``,
        and overflow errors to per-round execution (``fused=False``), the
        host just syncs ~K× less often.  ``sequential``/``threads`` always
        run the honest per-round host loop (they are the host-scheduling
        baselines; see docs/architecture.md) with the fused done-reducer.
        """
        t0 = _time.perf_counter()
        self._require_open()
        if rounds_per_dispatch < 1:
            raise ValueError("rounds_per_dispatch must be >= 1")
        if fused is None:
            fused = self.backend in ("vmap", "shard_map")
        if fused and self.backend in ("vmap", "shard_map"):
            mega = self._vmap_mega if self.backend == "vmap" else self._shard_mega
            done = over = False
            ran = 0
            while ran < max_rounds:
                k = min(rounds_per_dispatch, max_rounds - ran)
                self.states, self.pending, i, d, o = mega(
                    self.states, self.pending,
                    jnp.int32(ran), jnp.int32(k), jnp.int32(check_every),
                )
                i = int(i)  # the one host sync per dispatch
                ran += i
                self.rounds_run += i
                done, over = bool(d), bool(o)
                if done or over:
                    break
            if over or not done:
                # a watermark tripped at a check point, or the loop exhausted
                # max_rounds without the predicate ever seeing the last rounds
                self._check_overflow()
        else:
            for r in range(max_rounds):
                self.round()
                if (r + 1) % check_every == 0 and self.done():
                    break
            else:
                self._check_overflow()  # done() may never have seen the last rounds
        self.block_until_ready()
        return self.rounds_run, _time.perf_counter() - t0

    # ------------------------------------------------------------------
    def result_states(self):
        """Stacked (S, ...) states regardless of backend."""
        return self._stacked()

    def sim_time(self):
        return np.asarray(self._stacked()["time"])

    def stats(self):
        states = self._stacked()
        st = states["stats"]
        return {
            "instructions": np.asarray(st["instrs"]),
            "messages": np.asarray(st["msgs"]),
            "txn_histogram": np.asarray(st["txn_hist"]).sum(0),
            "cache": {
                "d_hits": np.asarray(states["dcache"]["hits"]),
                "d_misses": np.asarray(states["dcache"]["misses"]),
            },
            "dram": {
                "reads": np.asarray(states["dram"]["reads"]),
                "writes": np.asarray(states["dram"]["writes"]),
            },
            "cim_ops": np.asarray(states["cims"]["ops"]),
            "snn": {
                "spikes": np.asarray(states["cims"]["spikes_total"]),
                "ticks": np.asarray(states["cims"]["ticks"]),
            },
        }
