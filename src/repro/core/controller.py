"""Simulation controller: quantum stepping + time-decoupled synchronization
(paper §IV, Fig. 2/3) with four execution backends.

Per round (= the paper's ``exec`` + ``sync``):

  limit_i = min_{j≠i} (time_j + latency[j, i])      # decoupling bound
  states'_i, outbox_i = segment_step(states_i, pending_i, limit_i)
  pending' = merge(pending, route(outboxes))        # sync

Backends for the ``exec`` phase (DESIGN.md §2):
  sequential — one host thread steps segments one after another: the
               conventional SystemC baseline ("sq");
  vmap       — segments stacked and stepped as one vectorized program: the
               single-device parallel backend ("pll" on a 1-core host);
  threads    — one host thread per segment (the paper's literal mechanism;
               only wins on multi-core hosts);
  shard_map  — one mesh device per segment; routing becomes an all-gather
               over the ``segment`` axis.  This is the production backend
               the multi-pod dry-run lowers.

All four produce bit-identical simulation results (property-tested): time
decoupling changes wall-clock interleaving, never simulated semantics.

The stacked backends (``vmap``/``shard_map``) additionally run the round
loop itself device-resident: ``run()`` dispatches a fused *megastep* — one
jitted ``jax.lax.while_loop`` that executes up to ``rounds_per_dispatch``
exec+sync rounds per host dispatch, evaluating the termination predicate
and the sticky overflow watermarks on-device (``platform.termination_flags``)
so the host syncs one tiny scalar tuple per dispatch instead of four
``bool(jnp.any(...))`` round-trips per ``check_every`` rounds.  Results,
round counts, and overflow errors are bit-identical to per-round execution
(``fused=False``).  ``sequential``/``threads`` keep their honest host-side
per-round loop (they *are* the host-scheduling baselines) but share the
fused single-sync done-reducer.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time as _time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.obs import trace as obs_trace
from repro.vp import platform as pf


_FN_CACHE: dict = {}  # (cfg, quantum, s, obs) -> compiled fns; benchmarks
                      # rebuild controllers per workload with identical shapes

# the single host-transfer primitive for dispatch-boundary syncs: every
# fused-dispatch fetch (round count + flags + telemetry ring) goes through
# one call to this, so tests can monkeypatch it to count device syncs and
# prove the one-sync-per-dispatch contract (tests/test_conformance.py)
_HOST_FETCH = jax.device_get


@dataclasses.dataclass
class Controller:
    cfg: pf.VPConfig
    states: object  # stacked (S, ...) pytree
    pending: object  # stacked (S, IN_CAP)
    backend: str = "vmap"
    quantum: int = 10_000
    mesh: object = None  # shard_map backend only
    rounds_run: int = 0
    obs: object = None  # obs.trace.TraceConfig, or None = tracing compiled out

    def __post_init__(self):
        # own the state: round fns donate their inputs, so the caller's
        # arrays must not be shared with this controller
        self.states = jax.tree.map(jnp.copy, self.states)
        self.pending = jax.tree.map(jnp.copy, self.pending)
        # telemetry (obs/): attach one trace ring per segment INSIDE the
        # state pytree, so the megaloop carries it and the step appends to
        # it in traced code; host-side bookkeeping for drained batches.
        # Attached before the list-mode split so every backend carries it.
        self.dispatches = 0      # fused megaloop dispatches issued
        self.dispatch_syncs = 0  # _HOST_FETCH calls from the fused loop
        self.trace_lost = 0      # events dropped to ring capacity
        self._events = []        # drained batches (np structured arrays)
        self._finished = False   # a run() observed clean termination
        if self.obs is not None and "trace" not in self.states:
            cap = int(self.obs.capacity)
            self.states = {
                **self.states,
                "trace": jax.vmap(lambda _: obs_trace.ring_state(cap))(
                    jnp.arange(self.cfg.n_segments)),
            }
        # the CPU-free fast path (VPConfig.has_cpu=False: no slot scan, no
        # MMIO inbox handling, no dense completion) is only valid while
        # nothing but AER spikes can circulate.  The builder guarantees that
        # for its own wiring, but callers may hand-inject MMIO/DMA messages
        # into the initial pending box — detect that once and fall back to
        # the full step (one host check at construction, never per round)
        if not self.cfg.has_cpu:
            injected = np.asarray(
                self.pending["valid"] & (self.pending["kind"] != ch.MSG_SPIKE)
            )
            if injected.any():
                self.cfg = dataclasses.replace(self.cfg, has_cpu=True)
        self.lat = self.cfg.latency_matrix()
        # sequential/threads keep per-segment state as persistent lists —
        # the honest "sq" baseline must not pay per-round slice/stack of the
        # 4 MB DRAM image (that would inflate the parallel speedup)
        self._list_mode = self.backend in ("sequential", "threads")
        if self._list_mode:
            s = self.cfg.n_segments
            take = lambda t, i: jax.tree.map(lambda x: x[i], t)
            self._states_l = [take(self.states, i) for i in range(s)]
            self._pending_l = [take(self.pending, i) for i in range(s)]
        # threads backend: one persistent pool for the controller's life —
        # creating and tearing down a ThreadPoolExecutor every round would
        # penalize the paper's literal parallel mechanism with pure host
        # overhead (thread spawn/join per quantum)
        self._pool = (
            cf.ThreadPoolExecutor(max_workers=self.cfg.n_segments,
                                  thread_name_prefix="vp-seg")
            if self.backend == "threads" else None
        )
        step = pf.make_segment_step(self.cfg, self.quantum, self.obs)
        s = self.cfg.n_segments
        big = jnp.int32(2**30)
        # locals, NOT self.*, inside the jitted closures below: _FN_CACHE
        # outlives controllers, and a closure over `self` would pin the
        # first instance's entire copied state (MB of DRAM image per
        # segment) for process lifetime
        cfg = self.cfg
        lat = self.lat
        quantum = self.quantum

        def limits(times):
            # limit_i = min_{j != i}(t_j + lat[j, i]); single segment: t + q
            tl = times[:, None] + lat  # (src, dst)
            eye = jnp.eye(s, dtype=bool)
            tl = jnp.where(eye, big, tl)
            lim = tl.min(axis=0)
            if s == 1:
                lim = times + quantum
            return lim

        def vmap_round(states, pending):
            lim = limits(states["time"])
            states, outboxes, pending = jax.vmap(step)(states, pending, lim)
            fresh = ch.route(outboxes, lat, cfg.in_cap)
            pending = jax.vmap(ch.merge_pending)(pending, fresh)
            return states, pending

        def megaloop(round_fn):
            """Device-resident round loop: up to ``k`` rounds of ``round_fn``
            inside one ``lax.while_loop``, with the termination predicate and
            sticky overflow watermarks evaluated in traced code at the same
            points the host loop would (every ``check_every``-th round since
            ``run()`` started, ``r0`` rounds ago).  The host sees one scalar
            tuple per dispatch.  ``done`` means clean termination; ``over``
            means a watermark tripped at a check point — the host re-raises
            with the detailed message (the loop stops at the same round the
            per-round path would, so the message is identical too)."""

            def mega(states, pending, r0, k, check_every):
                def cond(carry):
                    _, _, i, done, over = carry
                    return ~(done | over) & (i < k)

                def body(carry):
                    st, pen, i, _, _ = carry
                    st, pen = round_fn(st, pen)
                    i = i + 1
                    at_check = ((r0 + i) % check_every) == 0

                    def checked(_):
                        done, in_over, out_over, st_over, late, _tr = \
                            pf.termination_flags(
                                st, pen, cfg.in_cap, cfg.out_cap, cfg.store_log)
                        # the trace-overflow flag (6) is informational and
                        # never stops the loop: telemetry loss must not
                        # change termination behavior (obs/trace.py).  Under
                        # the graceful-degradation overflow policy
                        # (faults.FaultConfig(on_overflow="drop")) the
                        # channel watermarks stop being fatal too — overflow
                        # is counted spike loss and the run continues; the
                        # program-bug flags (store log, late MMIO) still
                        # abort.  Static branch: the policy is part of the
                        # cached-function key, like every fault gate.
                        if cfg.faults is not None and cfg.faults.drop_overflow:
                            over = st_over | late
                        else:
                            over = in_over | out_over | st_over | late
                        return done & ~over, over

                    # cond, not where: non-check rounds skip the reductions
                    done, over = jax.lax.cond(
                        at_check, checked,
                        lambda _: (jnp.array(False), jnp.array(False)), None)
                    return st, pen, i, done, over

                zero, false = jnp.int32(0), jnp.array(False)
                return jax.lax.while_loop(
                    cond, body, (states, pending, zero, false, false)
                )

            return mega

        key = (self.cfg, self.quantum, s, self.obs)
        if key not in _FN_CACHE:
            _FN_CACHE[key] = {
                "vmap_round": jax.jit(vmap_round, donate_argnums=(0, 1)),
                "vmap_mega": jax.jit(megaloop(vmap_round), donate_argnums=(0, 1)),
                "flags": jax.jit(
                    lambda states, pending: jnp.stack(pf.termination_flags(
                        states, pending, cfg.in_cap, cfg.out_cap, cfg.store_log))
                ),
                "step_one": jax.jit(step),
                "limits": jax.jit(limits),
                "route": jax.jit(lambda outboxes: ch.route(outboxes, lat, cfg.in_cap)),
                "merge_one": jax.jit(ch.merge_pending, donate_argnums=(0,)),
            }
        fns = _FN_CACHE[key]
        self._vmap_round = fns["vmap_round"]
        self._vmap_mega = fns["vmap_mega"]
        self._flags_fn = fns["flags"]
        self._step_one = fns["step_one"]
        self._limits = fns["limits"]
        self._route = fns["route"]
        self._merge_one = fns["merge_one"]

        if self.backend == "shard_map":
            from jax.sharding import PartitionSpec as P

            assert self.mesh is not None, "shard_map backend needs a mesh"

            def shard_round(states, pending):
                def body(states1, pending1):
                    # leading segment axis is mapped: local shapes (1, ...)
                    my = jax.tree.map(lambda x: x[0], states1)
                    pen = jax.tree.map(lambda x: x[0], pending1)
                    seg_times = jax.lax.all_gather(my["time"], "segment")
                    i = jax.lax.axis_index("segment")
                    tl = seg_times + self.lat[:, i]
                    tl = jnp.where(jnp.arange(s) == i, big, tl)
                    lim = tl.min()
                    st, outbox, pen = step(my, pen, lim)
                    all_out = jax.lax.all_gather(outbox, "segment")  # (S, cap)
                    t_avail = all_out["t_emit"] + self.lat[
                        jnp.repeat(jnp.arange(s), self.cfg.out_cap).reshape(s, self.cfg.out_cap), i
                    ]
                    flat_valid = (all_out["valid"] & (all_out["dst"] == i)).reshape(-1)
                    rank = jnp.cumsum(flat_valid.astype(jnp.int32)) - 1
                    # dead lanes scatter out-of-bounds and drop (channel.py's
                    # "never write a dead slot" rule); past-cap lanes drop
                    # too — same drop-the-tail semantics as route(), so the
                    # graceful-degradation overflow policy loses the
                    # identical messages on this backend as on the others
                    # (count below still records true demand for the
                    # watermark and the lost_total accounting)
                    pos = jnp.where(flat_valid & (rank < self.cfg.in_cap),
                                    rank, self.cfg.in_cap)
                    fresh = ch.empty_pending(self.cfg.in_cap)
                    for f, src in (("kind", all_out["kind"]), ("addr", all_out["addr"]),
                                   ("data", all_out["data"]), ("t_avail", t_avail)):
                        fresh[f] = fresh[f].at[pos].set(src.reshape(-1), mode="drop")
                    fresh["valid"] = fresh["valid"].at[pos].set(flat_valid, mode="drop")
                    fresh["count"] = flat_valid.sum().astype(jnp.int32)
                    pen = ch.merge_pending(pen, fresh)
                    exp = lambda t: jax.tree.map(lambda x: x[None], t)
                    return exp(st), exp(pen)

                from repro.compat import shard_map

                return shard_map(
                    body,
                    mesh=self.mesh,
                    in_specs=(P("segment"), P("segment")),
                    out_specs=(P("segment"), P("segment")),
                )(states, pending)

            self._shard_round = jax.jit(shard_round, donate_argnums=(0, 1))
            # mesh-dependent, so per-instance rather than in _FN_CACHE; the
            # while_loop wraps the shard_map call and the flags reduce over
            # the sharded carry (XLA inserts the all-reduce)
            self._shard_mega = jax.jit(megaloop(shard_round), donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def _require_open(self):
        if getattr(self, "_closed", False):
            raise RuntimeError(
                "Controller is closed: close() released its host resources "
                "(the threads backend's worker pool); build a new Controller "
                "to run again"
            )

    def round(self):
        self._require_open()
        s = self.cfg.n_segments
        if self.backend == "vmap":
            self.states, self.pending = self._vmap_round(self.states, self.pending)
        elif self.backend == "shard_map":
            self.states, self.pending = self._shard_round(self.states, self.pending)
        elif self._list_mode:
            times = jnp.stack([st["time"] for st in self._states_l])
            lim = self._limits(times)

            def one(i):
                return self._step_one(self._states_l[i], self._pending_l[i], lim[i])

            if self.backend == "sequential":
                results = [one(i) for i in range(s)]
            else:
                results = list(self._pool.map(one, range(s)))
            self._states_l = [r[0] for r in results]
            stack = lambda xs: jax.tree.map(lambda *v: jnp.stack(v), *xs)
            outboxes = stack([r[1] for r in results])  # ~100 KB each: cheap
            fresh = self._route(outboxes)
            take = lambda t, i: jax.tree.map(lambda x: x[i], t)
            self._pending_l = [
                self._merge_one(r[2], take(fresh, i)) for i, r in enumerate(results)
            ]
        else:
            raise ValueError(self.backend)
        self.rounds_run += 1

    def _stacked(self):
        if self._list_mode:
            return jax.tree.map(lambda *v: jnp.stack(v), *self._states_l)
        return self.states

    def _pending_stacked(self):
        if self._list_mode:
            return jax.tree.map(lambda *v: jnp.stack(v), *self._pending_l)
        return self.pending

    @staticmethod
    def _flag_detail(flag_name, values, cap, kwarg=None):
        """Shared watermark formatter (both dispatch paths re-raise through
        ``_check_overflow``, so fused and per-round messages stay byte
        identical): names the tripped flag, the first segment past the cap,
        and the cap itself, then the full per-segment watermark vector.

        ``kwarg`` names the build()/build_snn keyword that sizes this cap;
        the watermark records true demand, so its peak IS the smallest
        capacity that would have absorbed the burst — the hint turns the
        abort into a one-edit remediation."""
        values = np.asarray(values)
        seg = int(np.flatnonzero(values > cap)[0])
        hint = "" if kwarg is None else (
            f"; smallest sufficient {kwarg}={int(values.max())}")
        return (f"flag '{flag_name}' tripped first at segment {seg} "
                f"({int(values[seg])} > cap {cap}; per-segment watermarks "
                f"{values.tolist()}{hint})")

    def _check_overflow(self, pending=None, states=None):
        drop = self.cfg.faults is not None and self.cfg.faults.drop_overflow
        pending = self._pending_stacked() if pending is None else pending
        states = self._stacked() if states is None else states
        msg = overflow_error(states, pending, in_cap=self.cfg.in_cap,
                             out_cap=self.cfg.out_cap,
                             store_log=self.cfg.store_log, drop=drop)
        if msg is not None:
            raise RuntimeError(msg)

    def done(self) -> bool:
        """Termination check + loud overflow validation (one device sync).

        The predicate itself lives in traced code
        (``platform.termination_flags`` — see its docstring for the exact
        semantics: running CPUs, in-flight CIM OPs, drainable spike-mode
        work, pending spike-count readbacks, pending messages); here it is
        evaluated as one fused jitted call returning a single (6,) bool
        array — done + the inbox/outbox/store-log watermarks, the
        late-SNN-MMIO flag, and the informational trace-ring overflow
        flag — instead of separate ``bool(jnp.any(...))`` host round-trips.
        """
        d, in_over, out_over, store_over, mmio_late, _trace_over = np.asarray(
            self._flags_fn(self._stacked(), self._pending_stacked())
        )
        drop = self.cfg.faults is not None and self.cfg.faults.drop_overflow
        if ((in_over or out_over) and not drop) or store_over or mmio_late:
            self._check_overflow()  # raises with the detailed watermark message
        return bool(d)

    def block_until_ready(self):
        """Wait for this controller's device state to materialize.

        Public replacement for benchmarks reaching into ``_states_l`` /
        ``_list_mode``; returns self so warm-up reads chain."""
        if self._list_mode:
            jax.block_until_ready((self._states_l, self._pending_l))
        else:
            jax.block_until_ready((self.states, self.pending))
        return self

    def close(self):
        """Release host resources (the threads backend's persistent pool).

        Idempotent; a closed controller refuses to ``run``/``round`` with a
        clear error instead of dying inside the round machinery.  Reading
        results (``result_states``/``stats``/``done``) stays valid."""
        if getattr(self, "_pool", None) is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        self._closed = True

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def _fetch(self, tree):
        """The dispatch-boundary host sync: one ``jax.device_get`` of the
        (round-count, done, over[, trace-ring]) tuple.  Counted so the
        one-sync-per-dispatch contract is testable with telemetry on."""
        self.dispatch_syncs += 1
        return _HOST_FETCH(tree)

    def _ingest(self, host_ring, on_telemetry=None):
        """Account a fetched (host-side) ring: collect events, track loss."""
        events, lost = obs_trace.drain(host_ring)
        self.trace_lost += lost
        if len(events):
            self._events.append(events)
            if on_telemetry is not None:
                on_telemetry(events)

    def drain_telemetry(self, on_telemetry=None):
        """Fetch + reset the device trace rings; returns the drained batch.

        For the host-loop backends this *is* a device sync, so ``run``
        calls it only at ``check_every`` boundaries (where ``done()``
        already syncs) and at the end; the fused megaloop never calls it —
        its drain piggybacks on the dispatch fetch (``_fetch``).  No-op
        (empty batch) when tracing is disabled.
        """
        if self.obs is None:
            return np.empty(0, obs_trace.EVENT_DTYPE)
        if self._list_mode:
            ring = jax.tree.map(
                lambda *v: jnp.stack(v), *[st["trace"] for st in self._states_l])
            host = _HOST_FETCH(ring)
            self._states_l = [
                {**st, "trace": obs_trace.reset(st["trace"])}
                for st in self._states_l
            ]
        else:
            ring = self.states["trace"]
            host = _HOST_FETCH(ring)
            self.states = {**self.states, "trace": obs_trace.reset(ring)}
        before = len(self._events)
        self._ingest(host, on_telemetry)
        return self._events[-1] if len(self._events) > before \
            else np.empty(0, obs_trace.EVENT_DTYPE)

    def trace_events(self):
        """All telemetry drained so far, one structured array
        (obs.trace.EVENT_DTYPE).  Batches are time-sorted per drain;
        export.to_chrome_trace handles global ordering."""
        if not self._events:
            return np.empty(0, obs_trace.EVENT_DTYPE)
        return np.concatenate(self._events)

    def run(self, max_rounds: int = 10_000, check_every: int = 4,
            fused: bool | None = None, rounds_per_dispatch: int = 256,
            on_telemetry=None):
        """Run to completion; returns (rounds, host_seconds).

        ``vmap``/``shard_map`` default to the device-resident megaloop
        (``fused=True``): up to ``rounds_per_dispatch`` rounds execute per
        host dispatch inside one jitted ``lax.while_loop`` that checks the
        termination predicate and overflow watermarks on-device at every
        ``check_every``-th round — bit-identical results, ``rounds_run``,
        and overflow errors to per-round execution (``fused=False``), the
        host just syncs ~K× less often.  ``sequential``/``threads`` always
        run the honest per-round host loop (they are the host-scheduling
        baselines; see docs/architecture.md) with the fused done-reducer.

        ``on_telemetry`` (requires ``obs``) receives each drained batch of
        trace events (np structured array) as it reaches the host — once
        per fused dispatch, or at ``check_every`` boundaries on the
        host-loop paths.  The fused drain piggybacks on the existing
        dispatch sync (the flags tuple and the ring travel in ONE
        ``jax.device_get``), so telemetry adds zero extra device syncs.
        """
        t0 = _time.perf_counter()
        self._require_open()
        if self._finished:
            # re-entry on a finished controller: termination is final
            # (platform.termination_flags — with an empty buffer and all
            # neurons subthreshold, idling can never un-idle), so a second
            # run() must be free.  The megaloop body unconditionally executes
            # one round before its first check, and the per-round path rounds
            # before checking too — without this short-circuit a re-entered
            # run would burn a dispatch, mutate rounds_run/dispatches, and
            # re-walk the watermark checks.  The serving loop
            # (serve/snn_serve.py) calls run() repeatedly, so this is load
            # bearing, not cosmetic.
            return self.rounds_run, _time.perf_counter() - t0
        if rounds_per_dispatch < 1:
            raise ValueError("rounds_per_dispatch must be >= 1")
        if fused is None:
            fused = self.backend in ("vmap", "shard_map")
        if fused and self.backend in ("vmap", "shard_map"):
            mega = self._vmap_mega if self.backend == "vmap" else self._shard_mega
            done = over = False
            ran = 0
            while ran < max_rounds:
                k = min(rounds_per_dispatch, max_rounds - ran)
                self.states, self.pending, i, d, o = mega(
                    self.states, self.pending,
                    jnp.int32(ran), jnp.int32(k), jnp.int32(check_every),
                )
                self.dispatches += 1
                # the one host sync per dispatch: scalars AND the telemetry
                # ring come back in a single transfer
                if self.obs is None:
                    i, d, o = self._fetch((i, d, o))
                else:
                    i, d, o, ring = self._fetch(
                        (i, d, o, self.states["trace"]))
                    self._ingest(ring, on_telemetry)
                    self.states = {**self.states,
                                   "trace": obs_trace.reset(self.states["trace"])}
                i = int(i)
                ran += i
                self.rounds_run += i
                done, over = bool(d), bool(o)
                if done or over:
                    break
            if over or not done:
                # a watermark tripped at a check point, or the loop exhausted
                # max_rounds without the predicate ever seeing the last rounds
                self._check_overflow()
            self._finished = done and not over
        else:
            for r in range(max_rounds):
                self.round()
                if (r + 1) % check_every == 0:
                    try:
                        finished = self.done()
                    finally:
                        # drain even when done() raises on a watermark, so
                        # the telemetry preceding a crash is preserved —
                        # same guarantee as the fused path (which drains on
                        # the dispatch fetch before re-raising)
                        if self.obs is not None:
                            self.drain_telemetry(on_telemetry)
                    if finished:
                        self._finished = True
                        break
            else:
                self._check_overflow()  # done() may never have seen the last rounds
            if self.obs is not None:
                self.drain_telemetry(on_telemetry)
        self.block_until_ready()
        return self.rounds_run, _time.perf_counter() - t0

    # ------------------------------------------------------------------
    def result_states(self):
        """Stacked (S, ...) states regardless of backend."""
        return self._stacked()

    def sim_time(self):
        return np.asarray(self._stacked()["time"])

    def stats(self):
        """Historical coarse stats dict — a back-compat shim over the typed
        metrics registry (obs/metrics.py ``legacy_stats``); prefer
        ``metrics()`` for new code."""
        from repro.obs import metrics as obs_metrics

        return obs_metrics.legacy_stats(self._stacked())

    def metrics(self):
        """Typed metrics snapshot: ``{name: ndarray}`` over every metric in
        the obs/metrics.py registry (counters, gauges, histograms —
        including the channel occupancy/routed counters the stats() dict
        never exposed)."""
        from repro.obs import metrics as obs_metrics

        return obs_metrics.collect(self._stacked(), self._pending_stacked())


def overflow_error(states, pending, *, in_cap: int, out_cap: int,
                   store_log: int, drop: bool = False):
    """The detailed watermark error message, or ``None`` when clean.

    Loud overflow sentinels: merge_pending and the segment step keep sticky
    high-water marks of the capacity they needed; past-cap messages are
    silently lost (bulk appends/merges truncate, single appends clip onto
    the last slot), so any watermark beyond capacity means messages were
    dropped at some point — even if the box drained since.  Under graceful
    degradation (``faults.FaultConfig(on_overflow="drop")``, ``drop=True``)
    inbox/outbox overflow is counted spike loss, not an abort — only the
    program-bug flags (store log, late MMIO) stay fatal.

    Module-level so both raisers share one formatter: ``Controller``
    (fused and per-round paths — messages stay byte identical) and the
    serving job axis (serve/snn_serve.py converts a job's flag into a
    per-request error against the job's OWN caps instead of killing the
    bucket).
    """
    watermark = np.asarray(pending["max_count"])
    if not drop and (watermark > in_cap).any():
        return (
            "pending inbox overflow: "
            f"{Controller._flag_detail('inbox', watermark, in_cap, 'in_cap')}; "
            "raise in_cap (builder kwarg) or thin the workload's traffic"
        )
    out_peak = np.asarray(states["stats"]["outbox_peak"])
    if not drop and (out_peak > out_cap).any():
        return (
            "outbox overflow: "
            f"{Controller._flag_detail('outbox', out_peak, out_cap, 'out_cap')}; "
            "raise out_cap (builder kwarg) or thin the workload's traffic"
        )
    store_peak = np.asarray(states["stats"]["store_peak"])
    if (store_peak > store_log).any():
        return (
            "DRAM store-log overflow: "
            f"{Controller._flag_detail('store_log', store_peak, store_log, 'store_log')}"
            " stores in one quantum; raise store_log "
            "(builder kwarg) or shrink the quantum"
        )
    mmio_late = np.asarray(states["stats"]["snn_mmio_late"])
    if (mmio_late > 0).any():
        return (
            "late SNN MMIO ops: "
            f"{Controller._flag_detail('snn_mmio_late', mmio_late, 0)}: a "
            "CIM_REG_SPIKE store executed at/after its target tick's grid "
            "time, or a CIM_REG_COUNTS readback was served after the unit "
            "ticked past the requested count — the result would depend on "
            "round timing, not the tick grid.  Issue the op earlier in "
            "the program, or raise tick_period (builder kwarg) so the "
            "injection window covers it"
        )
    return None


# ---------------------------------------------------------------------------
# job-axis megaloop (fleet serving — serve/snn_serve.py)
#
# The Controller vmaps over the *segments of one platform*; serving stacks a
# second leading axis of J independent platforms ("jobs") and runs them all
# inside ONE device-resident while_loop.  The jobs share a compiled shape
# (one VPConfig) but carry their own rasters, weights, fault seeds/masks and
# trace rings in the stacked state.
#
# vmap-of-while_loop would be wrong here: JAX batches a while_loop by running
# the body while ANY lane's cond holds, WITHOUT masking the finished lanes —
# a done job would keep mutating.  So the job loop is a single while_loop
# whose carry holds per-job (done, over, rounds) vectors and freezes finished
# jobs functionally: every state/pending leaf is `where(active, new, old)`.
# A frozen job's final state is its state at the first check round that saw
# it done — the same round its solo run stops at — so batched results are
# bit-identical to solo runs under the same (r0, check_every) cadence.
#
# Per-job caps ride as (J,) traced operands into the vmapped termination
# flags (platform.job_termination_flags): a cap-padded bucket (physical boxes
# sized to the bucket maximum) still trips each job's watermark against its
# OWN cap, at the same check round as solo.

_JOB_FN_CACHE: dict = {}  # (cfg, quantum, obs) -> jitted batched megaloop


def _job_megaloop(cfg, quantum, obs):
    step = pf.make_segment_step(cfg, quantum, obs)
    s = cfg.n_segments
    lat = cfg.latency_matrix()
    big = jnp.int32(2**30)

    def limits(times):
        tl = times[:, None] + lat
        tl = jnp.where(jnp.eye(s, dtype=bool), big, tl)
        lim = tl.min(axis=0)
        if s == 1:
            lim = times + quantum
        return lim

    def vmap_round(states, pending):
        lim = limits(states["time"])
        states, outboxes, pending = jax.vmap(step)(states, pending, lim)
        fresh = ch.route(outboxes, lat, cfg.in_cap)
        pending = jax.vmap(ch.merge_pending)(pending, fresh)
        return states, pending

    job_round = jax.vmap(vmap_round)

    def mega(states, pending, rounds, done, over,
             in_cap, out_cap, store_log, r0, k, check_every):
        """One dispatch of the batched job loop.

        ``states``/``pending`` are (J, S, ...) stacks; ``rounds``/``done``/
        ``over`` are the (J,) per-job carries from the previous dispatch
        (zeros/False for a fresh batch — padding lanes enter with
        ``done=True`` and are frozen from the first round); the caps are
        (J,) int32 per-job capacities.  ``r0`` is the shared round count of
        the still-active jobs (active jobs are lockstep: they have all been
        active since round 0, so they share one cadence) and ``check_every``
        the check period, exactly as in ``Controller.run``.  Returns
        ``(states, pending, rounds, done, over)``; the scalar iteration
        count stays internal so the sharded variant's outputs are all
        per-job.
        """

        def cond(carry):
            _st, _pen, i, _r, done, over = carry
            return jnp.any(~(done | over)) & (i < k)

        def body(carry):
            st, pen, i, rounds, done, over = carry
            active = ~(done | over)

            def freeze(new, old):
                keep = lambda n, o: jnp.where(
                    active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
                return jax.tree.map(keep, new, old)

            st_n, pen_n = job_round(st, pen)
            st, pen = freeze(st_n, st), freeze(pen_n, pen)
            rounds = rounds + active.astype(jnp.int32)
            i = i + 1
            at_check = ((r0 + i) % check_every) == 0

            def checked(_):
                d, in_o, out_o, st_o, late, _tr = pf.job_termination_flags(
                    st, pen, in_cap, out_cap, store_log)
                # same policy split as the solo megaloop: under the
                # graceful-degradation overflow policy the channel
                # watermarks are counted loss, not aborts
                if cfg.faults is not None and cfg.faults.drop_overflow:
                    o = st_o | late
                else:
                    o = in_o | out_o | st_o | late
                return d & ~o, o

            d, o = jax.lax.cond(
                at_check, checked,
                lambda _: (jnp.zeros_like(done), jnp.zeros_like(over)), None)
            done = done | (active & d)
            over = over | (active & o)
            return st, pen, i, rounds, done, over

        st, pen, _i, rounds, done, over = jax.lax.while_loop(
            cond, body, (states, pending, jnp.int32(0), rounds, done, over))
        return st, pen, rounds, done, over

    return mega


def job_mega_fn(cfg, quantum: int = 10_000, obs=None):
    """Cached jitted job-axis megaloop for ``cfg`` (single device).

    The jit retraces per batch size J, so one cache entry serves every
    bucket size of a workload shape — same lifetime story as
    ``_FN_CACHE``.
    """
    key = (cfg, quantum, obs)
    if key not in _JOB_FN_CACHE:
        _JOB_FN_CACHE[key] = jax.jit(
            _job_megaloop(cfg, quantum, obs), donate_argnums=(0, 1))
    return _JOB_FN_CACHE[key]


def sharded_job_mega_fn(cfg, mesh, quantum: int = 10_000, obs=None,
                        axis: str = "jobs"):
    """The job megaloop fanned across ``mesh`` devices over the job axis.

    Each device runs the batched while_loop on its local job shard
    independently — there are no collectives inside a round (routing is
    within-platform), so a device whose jobs all finish exits its loop
    early while the others keep running.  J must divide the mesh axis
    (the server pads buckets with inert ``done=True`` lanes to arrange
    that).  Mesh-dependent, so per-call rather than in the global cache —
    mirrors Controller's per-instance ``_shard_mega``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mega = _job_megaloop(cfg, quantum, obs)
    job = P(axis)
    rep = P()
    fn = shard_map(
        mega, mesh=mesh,
        in_specs=(job, job, job, job, job, job, job, job, rep, rep, rep),
        out_specs=(job, job, job, job, job),
    )
    return jax.jit(fn, donate_argnums=(0, 1))
