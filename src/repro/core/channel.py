"""Latency-annotated inter-segment channels (paper §IV-B).

Messages are TLM transactions crossing a segment boundary.  A message sent
at local time ``t`` over a channel with latency ``L`` becomes *visible* to
the receiver at ``t_avail = t + L``; the controller guarantees no receiver's
local time ever exceeds ``min_peers(t_peer + L)``, so a message can never
arrive in the receiver's past — the paper's time-decoupling legality rule,
property-tested in tests/test_core_decoupling.py.

Buffers are fixed-capacity structure-of-arrays so the whole simulation stays
jit/vmap/shard_map-friendly.  Routing is a pure function of the stacked
outboxes — in the shard_map backend it lowers to an all-gather over the
``segment`` mesh axis (the TPU analogue of the paper's shared-memory channel
objects).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# message kinds
MSG_W_DRAM = 0  # posted write to the DRAM-owning segment
MSG_W_CIM = 1  # CIM register write; addr = slot << 16 | reg_offset
MSG_W_SCRATCH = 2  # DMA write into a segment's scratch SRAM
MSG_R_DRAM = 3  # blocking read request; data = requesting cpu tag
MSG_R_RESP = 4  # read response; addr = tag
MSG_SPIKE = 5  # AER spike event; addr = slot << 16 | axon, data = weight (1)
               # Unlike MMIO kinds, spikes are NOT applied at arrival time:
               # the receiving spike-mode CIM unit integrates a spike at its
               # first tick T with t_avail <= T (vp/platform.py), so delivery
               # is tick-bucketed and bit-identical under every segmentation
               # as long as tick_period >= channel latency.

FIELDS = ("kind", "dst", "addr", "data", "t_emit")


def empty_box(cap: int):
    box = {f: jnp.zeros((cap,), jnp.int32) for f in FIELDS}
    box["valid"] = jnp.zeros((cap,), jnp.bool_)
    box["count"] = jnp.zeros((), jnp.int32)
    return box


def box_append(box, mask, kind, dst, addr, data, t_emit):
    """Append one message (if mask) at the current count.

    Masked appends scatter out-of-bounds and are dropped — never write a
    dead slot with stale values (duplicate scatter indices with different
    values are nondeterministic in XLA)."""
    cap = box["valid"].shape[0]
    i = jnp.where(mask, jnp.clip(box["count"], 0, cap - 1), cap)
    sel = lambda f, v: box[f].at[i].set(jnp.asarray(v, jnp.int32), mode="drop")
    out = dict(box)
    out["kind"] = sel("kind", kind)
    out["dst"] = sel("dst", dst)
    out["addr"] = sel("addr", addr)
    out["data"] = sel("data", data)
    out["t_emit"] = sel("t_emit", t_emit)
    out["valid"] = box["valid"].at[i].set(True, mode="drop")
    out["count"] = box["count"] + mask.astype(jnp.int32)
    return out


def box_append_bulk(box, mask, kind, dst, addr, data, t_emit):
    """Append a vector of messages (mask selects which) preserving order."""
    cap = box["valid"].shape[0]
    n = mask.shape[0]
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = jnp.where(mask, jnp.clip(box["count"] + rank, 0, cap - 1), cap)

    def sc(dest, vals):
        return dest.at[pos].set(vals.astype(jnp.int32), mode="drop")

    out = dict(box)
    out["kind"] = sc(box["kind"], jnp.broadcast_to(jnp.asarray(kind, jnp.int32), (n,)))
    out["dst"] = sc(box["dst"], jnp.broadcast_to(jnp.asarray(dst, jnp.int32), (n,)))
    out["addr"] = sc(box["addr"], jnp.broadcast_to(jnp.asarray(addr, jnp.int32), (n,)))
    out["data"] = sc(box["data"], jnp.broadcast_to(jnp.asarray(data, jnp.int32), (n,)))
    out["t_emit"] = sc(box["t_emit"], jnp.broadcast_to(jnp.asarray(t_emit, jnp.int32), (n,)))
    out["valid"] = box["valid"].at[pos].set(True, mode="drop")
    out["count"] = box["count"] + mask.sum().astype(jnp.int32)
    return out


def pack(box):
    """Compact valid entries to the front (stable)."""
    cap = box["valid"].shape[0]
    v = box["valid"]
    rank = jnp.cumsum(v.astype(jnp.int32)) - 1
    pos = jnp.where(v, jnp.clip(rank, 0, cap - 1), cap)
    out = {}
    for f in FIELDS:
        buf = jnp.zeros((cap,), jnp.int32)
        out[f] = buf.at[pos].set(box[f], mode="drop")
    vb = jnp.zeros((cap,), jnp.bool_)
    out["valid"] = vb.at[pos].set(True, mode="drop")
    out["count"] = v.sum().astype(jnp.int32)
    return out


def route(outboxes, latency, in_cap: int):
    """Stacked outboxes (S, cap) -> stacked fresh inboxes (S, in_cap).

    ``latency[src, dst]`` (int32 matrix) is added to each message's
    ``t_emit`` to form ``t_avail``.  Pure function — identical under every
    backend; the shard_map backend all-gathers the outboxes first.
    """
    s, cap = outboxes["valid"].shape
    src_ids = jnp.broadcast_to(jnp.arange(s)[:, None], (s, cap)).reshape(-1)
    flat = {f: outboxes[f].reshape(-1) for f in FIELDS}
    valid = outboxes["valid"].reshape(-1)
    dst = flat["dst"]
    t_avail = flat["t_emit"] + latency[src_ids, jnp.clip(dst, 0, s - 1)]

    def one_dst(d):
        m = valid & (dst == d)
        rank = jnp.cumsum(m.astype(jnp.int32)) - 1
        pos = jnp.where(m, jnp.clip(rank, 0, in_cap - 1), in_cap)
        box = {}
        for f in ("kind", "addr", "data"):
            buf = jnp.zeros((in_cap,), jnp.int32)
            box[f] = buf.at[pos].set(flat[f], mode="drop")
        ta = jnp.zeros((in_cap,), jnp.int32)
        box["t_avail"] = ta.at[pos].set(t_avail, mode="drop")
        vb = jnp.zeros((in_cap,), jnp.bool_)
        box["valid"] = vb.at[pos].set(m, mode="drop")
        box["count"] = m.sum().astype(jnp.int32)
        return box

    return jax.vmap(one_dst)(jnp.arange(s))


def merge_pending(pending, fresh):
    """Append fresh inbox messages after the surviving pending ones.

    ``max_count`` is a sticky high-water mark of the capacity the merge
    *needed* (``fresh["count"]`` carries route-level overflow too): past-cap
    scatters clip onto the last slot — a documented-nondeterministic
    overwrite — so the controller raises loudly when the watermark ever
    exceeds the capacity, even if later rounds drain the box back down.
    """
    cap = pending["valid"].shape[0]
    packed = pack_pending(pending)
    base = packed["count"]
    n = fresh["valid"].shape[0]
    m = fresh["valid"]
    pos = jnp.where(m, jnp.clip(base + jnp.arange(n), 0, cap - 1), cap)
    out = dict(packed)
    for f in ("kind", "addr", "data", "t_avail"):
        out[f] = packed[f].at[pos].set(fresh[f], mode="drop")
    out["valid"] = packed["valid"].at[pos].set(True, mode="drop")
    out["count"] = base + m.sum().astype(jnp.int32)
    out["max_count"] = jnp.maximum(pending["max_count"], base + fresh["count"])
    return out


def empty_pending(cap: int):
    box = {f: jnp.zeros((cap,), jnp.int32) for f in ("kind", "addr", "data", "t_avail")}
    box["valid"] = jnp.zeros((cap,), jnp.bool_)
    box["count"] = jnp.zeros((), jnp.int32)
    box["max_count"] = jnp.zeros((), jnp.int32)
    return box


def pack_pending(box):
    cap = box["valid"].shape[0]
    v = box["valid"]
    rank = jnp.cumsum(v.astype(jnp.int32)) - 1
    pos = jnp.where(v, jnp.clip(rank, 0, cap - 1), cap)
    out = {}
    for f in ("kind", "addr", "data", "t_avail"):
        buf = jnp.zeros((cap,), jnp.int32)
        out[f] = buf.at[pos].set(box[f], mode="drop")
    vb = jnp.zeros((cap,), jnp.bool_)
    out["valid"] = vb.at[pos].set(True, mode="drop")
    out["count"] = v.sum().astype(jnp.int32)
    return out
