"""Latency-annotated inter-segment channels (paper §IV-B).

Messages are TLM transactions crossing a segment boundary.  A message sent
at local time ``t`` over a channel with latency ``L`` becomes *visible* to
the receiver at ``t_avail = t + L``; the controller guarantees no receiver's
local time ever exceeds ``min_peers(t_peer + L)``, so a message can never
arrive in the receiver's past — the paper's time-decoupling legality rule,
property-tested in tests/test_core_decoupling.py.

Buffers are fixed-capacity structure-of-arrays so the whole simulation stays
jit/vmap/shard_map-friendly.  Routing is a pure function of the stacked
outboxes — in the shard_map backend it lowers to an all-gather over the
``segment`` mesh axis (the TPU analogue of the paper's shared-memory channel
objects).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# message kinds
MSG_W_DRAM = 0  # posted write to the DRAM-owning segment
MSG_W_CIM = 1  # CIM register write; addr = slot << 16 | reg_offset
MSG_W_SCRATCH = 2  # DMA write into a segment's scratch SRAM
MSG_R_DRAM = 3  # blocking read request; data = requesting cpu tag
MSG_R_RESP = 4  # read response; addr = tag
MSG_SPIKE = 5  # AER spike event; addr = slot << 16 | axon, data = weight (1)
               # Unlike MMIO kinds, spikes are NOT applied at arrival time:
               # the receiving spike-mode CIM unit integrates a spike at its
               # first tick T with t_avail <= T (vp/platform.py), so delivery
               # is tick-bucketed and bit-identical under every segmentation
               # as long as tick_period >= channel latency.

FIELDS = ("kind", "dst", "addr", "data", "t_emit")


def empty_box(cap: int):
    box = {f: jnp.zeros((cap,), jnp.int32) for f in FIELDS}
    box["valid"] = jnp.zeros((cap,), jnp.bool_)
    box["count"] = jnp.zeros((), jnp.int32)
    return box


def box_append(box, mask, kind, dst, addr, data, t_emit):
    """Append one message (if mask) at the current count.

    Masked appends scatter out-of-bounds and are dropped — never write a
    dead slot with stale values (duplicate scatter indices with different
    values are nondeterministic in XLA).  Past-capacity appends are dropped
    rather than clipped onto the last slot — the count still records true
    demand, so the watermark catches the overflow loudly (or, under the
    faults ``on_overflow="drop"`` policy, counts it as spike loss) without
    ever corrupting the newest resident message."""
    cap = box["valid"].shape[0]
    i = jnp.where(mask & (box["count"] < cap), box["count"], cap)
    sel = lambda f, v: box[f].at[i].set(jnp.asarray(v, jnp.int32), mode="drop")
    out = dict(box)
    out["kind"] = sel("kind", kind)
    out["dst"] = sel("dst", dst)
    out["addr"] = sel("addr", addr)
    out["data"] = sel("data", data)
    out["t_emit"] = sel("t_emit", t_emit)
    out["valid"] = box["valid"].at[i].set(True, mode="drop")
    out["count"] = box["count"] + mask.astype(jnp.int32)
    return out


def box_append_bulk(box, mask, kind, dst, addr, data, t_emit):
    """Append a vector of messages (mask selects which) preserving order.

    Gather formulation (see ``_compaction_order``): destination slot
    ``count + r`` reads the r-th mask-selected source lane — no scatters.
    Past-capacity appends truncate (the count still records true demand,
    so the ``outbox_peak`` watermark catches overflow loudly)."""
    cap = box["valid"].shape[0]
    n = mask.shape[0]
    order = _compaction_order(mask)
    k = mask.sum().astype(jnp.int32)
    j = jnp.arange(cap) - box["count"]
    src = order[jnp.clip(j, 0, n - 1)]
    write = (j >= 0) & (j < k)
    out = dict(box)
    for f, v in (("kind", kind), ("dst", dst), ("addr", addr),
                 ("data", data), ("t_emit", t_emit)):
        vals = jnp.broadcast_to(jnp.asarray(v, jnp.int32), (n,))
        out[f] = jnp.where(write, vals[src], box[f])
    out["valid"] = box["valid"] | write
    out["count"] = box["count"] + k
    return out


def _compaction_order(mask):
    """Stable gather indices putting ``mask``-selected lanes first, in lane
    order.  Compaction-by-gather: XLA CPU executes scatters lane-serially,
    so the old rank-scatter formulation dominated the whole sync phase on
    small platforms; a stable argsort of the mask plus dense gathers
    produces the identical compaction several times faster, inside and
    outside ``lax.while_loop``."""
    return jnp.argsort(~mask, stable=True)


def pack(box):
    """Compact valid entries to the front (stable)."""
    cap = box["valid"].shape[0]
    v = box["valid"]
    order = _compaction_order(v)
    keep = jnp.arange(cap) < v.sum()
    out = {f: jnp.where(keep, box[f][order], 0) for f in FIELDS}
    out["valid"] = keep
    out["count"] = v.sum().astype(jnp.int32)
    return out


def route(outboxes, latency, in_cap: int):
    """Stacked outboxes (S, cap) -> stacked fresh inboxes (S, in_cap).

    ``latency[src, dst]`` (int32 matrix) is added to each message's
    ``t_emit`` to form ``t_avail``.  Pure function — identical under every
    backend; the shard_map backend all-gathers the outboxes first.
    """
    s, cap = outboxes["valid"].shape
    src_ids = jnp.broadcast_to(jnp.arange(s)[:, None], (s, cap)).reshape(-1)
    flat = {f: outboxes[f].reshape(-1) for f in FIELDS}
    valid = outboxes["valid"].reshape(-1)
    dst = flat["dst"]
    t_avail = flat["t_emit"] + latency[src_ids, jnp.clip(dst, 0, s - 1)]

    def one_dst(d):
        # compaction-by-gather (see _compaction_order): lanes for d first,
        # in source order, truncated to in_cap (the count still records the
        # true demand, so merge_pending's watermark catches overflow)
        m = valid & (dst == d)
        order = _compaction_order(m)
        sel = order[jnp.clip(jnp.arange(in_cap), 0, order.shape[0] - 1)]
        n = m.sum().astype(jnp.int32)
        keep = (jnp.arange(in_cap) < n) & (jnp.arange(in_cap) < order.shape[0])
        box = {f: jnp.where(keep, flat[f][sel], 0) for f in ("kind", "addr", "data")}
        box["t_avail"] = jnp.where(keep, t_avail[sel], 0)
        box["valid"] = keep
        box["count"] = n
        return box

    return jax.vmap(one_dst)(jnp.arange(s))


def merge_pending(pending, fresh):
    """Append fresh inbox messages after the surviving pending ones.

    ``max_count`` is a sticky high-water mark of the capacity the merge
    *needed* (``fresh["count"]`` carries route-level overflow too): past-cap
    messages are truncated — silently lost — so the controller raises
    loudly when the watermark ever exceeds the capacity, even if later
    rounds drain the box back down.
    """
    cap = pending["valid"].shape[0]
    packed = pack_pending(pending)
    base = packed["count"]
    n = fresh["valid"].shape[0]
    # gather formulation of "fresh lane k lands at slot base + k": slot i
    # reads fresh lane i - base when that lane is valid, else keeps the
    # packed entry (zero past base) — no scatters, see _compaction_order
    j = jnp.arange(cap) - base
    jc = jnp.clip(j, 0, n - 1)
    from_fresh = (j >= 0) & (j < n) & fresh["valid"][jc]
    out = dict(packed)
    for f in ("kind", "addr", "data", "t_avail"):
        out[f] = jnp.where(from_fresh, fresh[f][jc], packed[f])
    out["valid"] = packed["valid"] | from_fresh
    out["count"] = base + fresh["valid"].sum().astype(jnp.int32)
    out["max_count"] = jnp.maximum(pending["max_count"], base + fresh["count"])
    # routed-traffic counter (obs/metrics.py): total messages ever routed
    # toward this segment — fresh["count"] carries true route demand, so
    # the counter is exact even when the merge truncates (which trips the
    # max_count watermark anyway).  pack_pending dropped the field.
    out["routed_total"] = pending["routed_total"] + fresh["count"]
    # spike-loss counter for the graceful-degradation overflow policy
    # (faults.FaultConfig(on_overflow="drop")): how many messages the
    # truncating merge actually discarded.  route() keeps exactly
    # ``cap - base`` fresh lanes when demand exceeds the box, so the loss
    # this merge is the demand past capacity.  Maintained unconditionally
    # (it is one add) — the controller only *consults* it under the drop
    # policy; under "raise" the max_count watermark aborts first.
    out["lost_total"] = pending["lost_total"] + jnp.maximum(
        base + fresh["count"] - cap, 0)
    return out


def inbox_overflowed(pending, cap: int):
    """Traced sticky-overflow flag for a (stacked) pending box.

    ``max_count`` is a *carried scalar* sentinel: it rides inside the
    simulation state through jit/vmap/shard_map and the controller's
    device-resident megaloop, so overflow detection never needs a host
    round-trip.  True iff the merge ever needed more than ``cap`` slots —
    past-cap messages are silently lost (bulk appends, merges, and single
    ``box_append`` all drop past-capacity writes), so a tripped flag
    means messages were dropped or corrupted at some point, even if the
    box drained since.  The controller converts the flag into the loud
    ``RuntimeError`` host-side.
    """
    return (pending["max_count"] > cap).any()


def empty_pending(cap: int):
    box = {f: jnp.zeros((cap,), jnp.int32) for f in ("kind", "addr", "data", "t_avail")}
    box["valid"] = jnp.zeros((cap,), jnp.bool_)
    box["count"] = jnp.zeros((), jnp.int32)
    box["max_count"] = jnp.zeros((), jnp.int32)
    box["routed_total"] = jnp.zeros((), jnp.int32)  # lifetime routed msgs
    box["lost_total"] = jnp.zeros((), jnp.int32)  # msgs lost to inbox overflow
    return box


def pack_pending(box):
    cap = box["valid"].shape[0]
    v = box["valid"]
    order = _compaction_order(v)
    keep = jnp.arange(cap) < v.sum()
    out = {f: jnp.where(keep, box[f][order], 0)
           for f in ("kind", "addr", "data", "t_avail")}
    out["valid"] = keep
    out["count"] = v.sum().astype(jnp.int32)
    return out
