"""VP segmentation strategies (paper §IV-C, Fig. 4a/4b) + platform builder.

A segmentation is a list of segment descriptors; the builder wires global
CIM ids to (segment, slot), assigns manager CPUs + scratch mailboxes,
preloads crossbar weights / DRAM contents / programs, and returns a stacked
state ready for the Controller.

Strategies:
  uniform        — every CPU segment gets an equal share of CIM-Units
                   (Fig. 4a: 2 segments × {1 CPU, 2 CIM}); DRAM in segment 0
  load_oriented  — one CPU manages all CIM-Units, the other is free; CIMs
                   live in their own segments (Fig. 4b: seg0 {CPU0, DRAM},
                   seg1 {CPU1}, seg2 {2 CIM}, seg3 {2 CIM})
  auto           — greedy balanced partition over per-module cost estimates
                   (the paper's "future work", implemented here)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.vp import isa, platform as pf
from repro.vp.assembler import assemble

# scratch mailbox layout (word offsets)
FLAG0, FLAG1 = 0, 1
OUT0, OUT1 = 256, 512
B_STAGE = 1024  # staged input vectors for offload mode


@dataclasses.dataclass
class SegmentDesc:
    cpu: bool = False
    dram: bool = False
    n_cims: int = 0
    cim_mgr: int = -1  # segment id of the managing CPU


def uniform(n_cpus: int = 2, cims_per_cpu: int = 2):
    return [
        SegmentDesc(cpu=True, dram=(i == 0), n_cims=cims_per_cpu, cim_mgr=i)
        for i in range(n_cpus)
    ]


def load_oriented():
    return [
        SegmentDesc(cpu=True, dram=True),
        SegmentDesc(cpu=True),
        SegmentDesc(n_cims=2, cim_mgr=1),
        SegmentDesc(n_cims=2, cim_mgr=1),
    ]


def auto_segmentation(module_costs: dict, n_segments: int):
    """Greedy longest-processing-time partition of modules onto segments.

    module_costs: {"cpu0": c, "cpu1": c, "dram": c, "cim0": c, ...} — host
    cost estimates (e.g. measured per-module event rates).  Returns segment
    descriptors with balanced total cost.  CPUs anchor segments; DRAM joins
    the heaviest-CPU segment's complement; CIMs fill greedily.
    """
    cpus = sorted([k for k in module_costs if k.startswith("cpu")])
    cims = sorted(
        [k for k in module_costs if k.startswith("cim")],
        key=lambda k: -module_costs[k],
    )
    n_segments = max(n_segments, len(cpus))
    descs = [SegmentDesc() for _ in range(n_segments)]
    loads = np.zeros(n_segments)
    for i, c in enumerate(cpus):
        descs[i].cpu = True
        descs[i].cim_mgr = i
        loads[i] += module_costs[c]
    # DRAM joins the lightest CPU segment
    d = int(np.argmin(loads[: len(cpus)]))
    descs[d].dram = True
    loads[d] += module_costs.get("dram", 0.0)
    mgr = int(np.argmax(loads[: len(cpus)]))  # heaviest CPU manages offload
    for c in cims:
        s = int(np.argmin(loads))
        descs[s].n_cims += 1
        if descs[s].cim_mgr < 0:
            descs[s].cim_mgr = mgr
        loads[s] += module_costs[c]
    return [d for d in descs if d.cpu or d.dram or d.n_cims]


def traffic_partition(widths, loads, traffic, n_segments: int,
                      slots_per_seg: int, refine_passes: int = 4,
                      pinned=None):
    """Spike-traffic-aware placement of shard groups onto segments.

    widths:  slots each group needs (a multi-crossbar column group occupies
             ``width`` co-located slots — it is atomic)
    loads:   per-group compute cost (synaptic ops/tick), the tie-breaker
    traffic: (G, G) measured spike rates — traffic[i, j] events/tick from
             group i to group j (profiling pass, snn/topology.py).  Cyclic
             nets make the matrix asymmetric (backward projections) and
             give it a nonzero diagonal (a stripe's lateral spikes to
             itself); the diagonal is placement-invariant self-traffic and
             is excluded from the cut up front, so lateral-heavy groups
             are neither attracted to nor repelled from any segment by
             their own chatter.
    pinned:  optional {group_index: segment_id} of groups whose placement
             is fixed — e.g. the *injector pseudo-group* of a hybrid
             workload (``snn.profile_traffic(injector=True)``): a width-0
             stand-in for the live CPU whose MMIO spike injection and
             count readback are real cross-segment events, so CPU<->CIM
             traffic enters the cut and pulls the chatty input/output
             stripes toward the CPU's segment.  Pinned groups seed their
             segments first and never move in refinement.

    Minimizes the cross-segment traffic cut under per-segment slot budgets:
    groups are seeded greedily in descending traffic-degree order, each
    into the feasible segment with the highest affinity (traffic to groups
    already there; ties prefer the fullest segment, then the lightest
    load — packing communicating groups densely is also what makes the
    host-side step cheaper: empty segments are dropped by the caller).
    A bounded single-move refinement pass then walks groups in index order
    and relocates any whose move strictly reduces the cut.  Deterministic.

    Returns an int array: segment id per group.
    """
    widths = np.asarray(widths, int)
    loads = np.asarray(loads, float)
    traffic = np.asarray(traffic, float)
    pinned = dict(pinned or {})
    g = len(widths)
    assert traffic.shape == (g, g) and len(loads) == g
    traffic = traffic - np.diag(np.diag(traffic))  # self-traffic never cut
    assert widths.max(initial=0) <= slots_per_seg, \
        "a column group is atomic: raise slots_per_seg to its width"
    assert n_segments * slots_per_seg >= widths.sum(), "not enough slots"
    sym = traffic + traffic.T
    assign = np.full(g, -1, int)
    used = np.zeros(n_segments, int)
    load = np.zeros(n_segments, float)

    for i, s in sorted(pinned.items()):
        assert 0 <= s < n_segments, f"pinned group {i} to missing segment {s}"
        assert used[s] + widths[i] <= slots_per_seg, \
            f"pinned group {i} does not fit segment {s}'s slot budget"
        assign[i] = s
        used[s] += widths[i]
        load[s] += loads[i]

    def affinity(i, s):
        members = np.flatnonzero(assign == s)
        return sym[i, members].sum()

    # widest groups first (first-fit-decreasing keeps atomic groups
    # placeable), then traffic degree so hot groups seed their segments
    order = sorted((i for i in range(g) if i not in pinned),
                   key=lambda i: (-widths[i], -sym[i].sum(), -loads[i], i))
    for i in order:
        feas = [s for s in range(n_segments) if used[s] + widths[i] <= slots_per_seg]
        if not feas:
            raise AssertionError(
                f"slot budgets too fragmented for a width-{widths[i]} group; "
                "raise n_segments or slots_per_seg"
            )
        s = max(feas, key=lambda s: (affinity(i, s), used[s], -load[s], -s))
        assign[i] = s
        used[s] += widths[i]
        load[s] += loads[i]

    for _ in range(refine_passes):
        moved = False
        for i in range(g):
            if i in pinned:
                continue
            best_s, best_gain = assign[i], 0.0
            here = affinity(i, assign[i])
            for s in range(n_segments):
                if s == assign[i] or used[s] + widths[i] > slots_per_seg:
                    continue
                gain = affinity(i, s) - here
                if gain > best_gain + 1e-12:
                    best_s, best_gain = s, gain
            if best_s != assign[i]:
                used[assign[i]] -= widths[i]
                load[assign[i]] -= loads[i]
                assign[i] = best_s
                used[best_s] += widths[i]
                load[best_s] += loads[i]
                moved = True
        if not moved:
            break
    return assign


def build(descs, *, programs=None, dram_words=None, crossbars=None,
          scratch_init=None, cim_init=None, channel_latency: int = 10_000,
          local_latency: int = 64, use_kernel: bool = False,
          in_cap: int | None = None, out_cap: int | None = None,
          store_log: int | None = None, faults=None, fault_uids=None):
    """Assemble the stacked simulation state.

    programs: {seg_id: asm_source or np.uint32 array}
    dram_words: np.int32 array preloaded at address 0
    crossbars: {global_cim_id: np.int8 (R, C)} preloaded weights
    scratch_init: {seg_id: {word_offset: np.int32 array}}
    cim_init: {global_cim_id: {field: value}} per-slot CIM state presets —
        e.g. spike-mode wiring (mode/thresh/leak/tick_period/dst_*, snn/).
        Preloading state is build-time configuration, like ``crossbars``;
        runtime reconfiguration goes through the MMIO registers.
    in_cap/out_cap/store_log: channel-box and store-log capacities (default:
        the generous ``platform`` module constants).  Every lane is touched
        every round, so right-sizing these to the workload is the dominant
        lever on small platforms' round cost; undersizing raises the loud
        sticky-watermark RuntimeError, never silently corrupts, and results
        are bit-identical across any caps that don't overflow.
    faults: ``repro.faults.FaultConfig`` or None (default).  Seeds the
        device-resident fault model: structural crossbar/neuron fault sites
        are drawn here per unit (host-side, placement-invariant) and baked
        into the stacked state; transport/overflow behaviour is compiled
        into the step via the static VPConfig field.  None compiles the
        whole subsystem out, bit-identical to a fault-free build.
    fault_uids: {global_cim_id: stable_uid} — placement-invariant unit
        identities for the fault PRNG (build_snn passes logical
        layer/stripe/tile coordinates).  Defaults to the global cim id.
    """
    assert channel_latency >= local_latency, \
        "intra-segment hops cannot be slower than cross-segment channels"
    # the SNN bit-exactness guarantee (tick-bucketed AER delivery) requires
    # every ticking spike-mode unit's tick to cover one channel hop
    for g, fields in (cim_init or {}).items():
        if int(fields.get("mode", 0)) == isa.CIM_MODE_SPIKE and \
                int(fields.get("tick_period", 0)) > 0:
            assert int(fields["tick_period"]) >= channel_latency, \
                f"cim {g}: tick_period must be >= channel latency (snn/topology.py)"
    n = len(descs)
    cim_seg, cim_slot, mgr_of = [], [], []
    for s, d in enumerate(descs):
        for k in range(d.n_cims):
            cim_seg.append(s)
            cim_slot.append(k)
            mgr_of.append(d.cim_mgr if d.cim_mgr >= 0 else s)
    # state shapes follow the richest wiring: AER fan-out tables (a wide
    # layer's stripe feeds every downstream shard) and column groups (a
    # contributor tile names an owner slot other than itself)
    snn_fanout = 1
    snn_grouped = False
    for g, fields in (cim_init or {}).items():
        if "dst_seg" in fields:
            snn_fanout = max(snn_fanout, int(np.size(fields["dst_seg"])))
        if "owner_slot" in fields and int(fields["owner_slot"]) != cim_slot[g]:
            snn_grouped = True
    # the global LIF tick grid: CPU spike injection (CIM_REG_SPIKE) is
    # tick-addressed, so the platform must know *the* tick pitch statically —
    # every ticking spike-mode unit shares it (build_snn always wires one
    # period; next_tick already assumes the shared grid P_k = (k+1)*period)
    periods = sorted({
        int(f["tick_period"]) for f in (cim_init or {}).values()
        if int(f.get("mode", 0)) == isa.CIM_MODE_SPIKE
        and int(f.get("tick_period", 0)) > 0
    })
    assert len(periods) <= 1, (
        f"spike-mode units disagree on tick_period ({periods}): the AER tick "
        "grid — and tick-addressed CPU spike injection — is global")
    snn_tick_period = periods[0] if periods else 0
    cfg = pf.VPConfig(
        n_segments=n,
        in_cap=pf.IN_CAP if in_cap is None else in_cap,
        out_cap=pf.OUT_CAP if out_cap is None else out_cap,
        store_log=pf.STORE_LOG if store_log is None else store_log,
        # a CPU whose segment has no program halts at build time below and
        # can never un-halt, so only programmed CPUs make the instruction
        # machinery live; without any (and no preset in-flight dense OP),
        # the step statically drops the slot scan, store log, MMIO inbox
        # handling, and dense-CIM completion (bit-identical — VPConfig.has_cpu)
        has_cpu=(any(d.cpu and s in (programs or {}) for s, d in enumerate(descs))
                 or any("state" in f or "busy_until" in f
                        for f in (cim_init or {}).values())),
        # size slot state for the densest segment (>= Table II's 2) — a
        # descriptor exceeding the default would otherwise scatter-clobber
        n_cim_slots=max([2] + [d.n_cims for d in descs]),
        dram_segment=[i for i, d in enumerate(descs) if d.dram][0] if any(d.dram for d in descs) else 0,
        channel_latency=channel_latency,
        local_latency=local_latency,
        cim_seg=tuple(cim_seg),
        cim_slot=tuple(cim_slot),
        use_kernel=use_kernel,
        has_snn=any(int(f.get("mode", 0)) == isa.CIM_MODE_SPIKE
                    for f in (cim_init or {}).values()),
        snn_fanout=snn_fanout,
        snn_grouped=snn_grouped,
        snn_tick_period=snn_tick_period,
        faults=faults,
    )
    states = []
    for s, d in enumerate(descs):
        st = pf.segment_state(cfg)
        st["seg_id"] = jnp.asarray(s, jnp.int32)
        st["cpu"] = dict(st["cpu"])
        st["cpu"]["present"] = jnp.asarray(d.cpu)
        st["dram_present"] = jnp.asarray(d.dram)
        cims = dict(st["cims"])
        pres = np.zeros(cfg.n_cim_slots, bool)
        pres[: d.n_cims] = True
        cims["present"] = jnp.asarray(pres)
        states.append({**st, "cims": cims})

    # wire each global CIM's manager mailbox: unit g managed by CPU seg m
    # gets flag FLAG{idx}, out OUT{idx} where idx = per-manager ordinal
    per_mgr_count: dict[int, int] = {}
    for g, (s, k) in enumerate(zip(cim_seg, cim_slot)):
        m = mgr_of[g]
        idx = per_mgr_count.get(m, 0)
        per_mgr_count[m] = idx + 1
        cims = dict(states[s]["cims"])
        cims["mgr_seg"] = cims["mgr_seg"].at[k].set(m)
        cims["flag_addr"] = cims["flag_addr"].at[k].set(FLAG0 + idx)
        cims["out_addr"] = cims["out_addr"].at[k].set(OUT0 + idx * 256)
        if crossbars and g in crossbars:
            w = np.zeros((256, 256), np.int8)
            src = np.asarray(crossbars[g], np.int8)
            w[: src.shape[0], : src.shape[1]] = src
            cims["weights"] = cims["weights"].at[k].set(jnp.asarray(w))
        for f, val in (cim_init or {}).get(g, {}).items():
            cims[f] = cims[f].at[k].set(jnp.asarray(val, cims[f].dtype))
        if faults is not None:
            from repro import faults as flt

            uid = (fault_uids or {}).get(g, g)
            if faults.has_transport_faults:
                cims["f_uid"] = cims["f_uid"].at[k].set(uid)
            if faults.has_xbar_faults or faults.has_neuron_faults:
                # structural sites are confined to the unit's programmed
                # region — a fault outside it would charge ghost neurons
                rows, cols = (np.asarray(crossbars[g]).shape
                              if crossbars and g in crossbars else (0, 0))
                masks = flt.unit_masks(faults, uid, rows, cols,
                                       cims["weights"].shape[-1])
                if faults.has_xbar_faults:
                    cims["f_and"] = cims["f_and"].at[k].set(masks["f_and"])
                    cims["f_xor"] = cims["f_xor"].at[k].set(masks["f_xor"])
                if faults.has_neuron_faults:
                    cims["f_dead"] = cims["f_dead"].at[k].set(masks["f_dead"])
                    cims["f_dth"] = cims["f_dth"].at[k].set(masks["f_dth"])
        states[s]["cims"] = cims

    if dram_words is not None:
        ds = cfg.dram_segment
        dram = dict(states[ds]["dram"])
        w = np.zeros(pf.DRAM_BACKING, np.int32)
        w[: len(dram_words)] = dram_words
        dram["data"] = jnp.asarray(w)
        states[ds]["dram"] = dram

    for s, prog in (programs or {}).items():
        words = assemble(prog) if isinstance(prog, str) else prog
        buf = np.zeros(pf.PROG_WORDS, np.uint32)
        buf[: len(words)] = words
        states[s]["prog"] = jnp.asarray(buf)
    # CPUs without a program halt immediately (otherwise they spin on
    # zero-words forever and the simulation never reports completion)
    for s, d in enumerate(descs):
        if d.cpu and s not in (programs or {}):
            cpu = dict(states[s]["cpu"])
            cpu["halted"] = jnp.asarray(True)
            states[s]["cpu"] = cpu

    for s, inits in (scratch_init or {}).items():
        sc = np.zeros(pf.SCRATCH_WORDS, np.int32)
        for off, arr in inits.items():
            sc[off : off + len(arr)] = arr
        states[s]["scratch"] = jnp.asarray(sc)

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    pending = jax.vmap(lambda _: ch.empty_pending(cfg.in_cap))(jnp.arange(n))
    return cfg, stacked, pending


def cim_global_base(g: int) -> int:
    return isa.CIM_BASE + g * isa.CIM_STRIDE


def mailbox_ordinals(descs) -> dict[int, int]:
    """global cim id -> mailbox ordinal within its manager CPU's scratch.

    Mirrors build()'s assignment (global-id order within each manager);
    programs MUST use these ordinals for flag/output addresses — e.g. under
    load-oriented segmentation one CPU manages all four units, so a program
    driving units (0, 2) polls flags 0 and 2, not 0 and 1."""
    mgr_of = []
    for s, d in enumerate(descs):
        for _ in range(d.n_cims):
            mgr_of.append(d.cim_mgr if d.cim_mgr >= 0 else s)
    per_mgr: dict[int, int] = {}
    out = {}
    for g, m in enumerate(mgr_of):
        out[g] = per_mgr.get(m, 0)
        per_mgr[m] = out[g] + 1
    return out
