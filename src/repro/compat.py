"""Version compatibility shims for the JAX APIs this repo leans on.

The production target is a recent JAX (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``); CI containers often carry an older release
(0.4.x) where those live under ``jax.experimental.shard_map`` / don't exist.
Everything here degrades to the old spelling with identical semantics so the
simulator and tests run unchanged on both.
"""
from __future__ import annotations

import contextlib

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Old JAX has no ambient-mesh concept for jit; entering the Mesh object
    itself covers the collective-lowering cases this repo uses.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext()


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (check_vma off) or the experimental fallback."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
