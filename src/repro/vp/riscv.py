"""Functional RISC-V ISS (RV32IM subset, real encodings, 32-bit datapath).

Decode is plain bit-slicing; execute is fully *branchless* — every
instruction class' result is computed and selected by the decoded class
mask.  That costs a few dozen scalar ops per instruction but contains **no
lax.switch/cond**, so the same compiled step vectorizes perfectly across
segments under ``vmap``/``shard_map`` (the paper's host threads, DESIGN.md
§2) with zero branch-divergence blowup.

Memory dispatch happens in platform.py (the module owns only the
architectural core); this file returns a memory-op descriptor per slot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.vp import isa


def cpu_state(pc: int = 0):
    return {
        "present": jnp.zeros((), jnp.bool_),
        "pc": jnp.asarray(pc, jnp.int32),
        "regs": jnp.zeros((32,), jnp.int32),
        "halted": jnp.zeros((), jnp.bool_),
        "waiting": jnp.zeros((), jnp.bool_),  # blocked on a remote read
        "instret": jnp.zeros((), jnp.int32),
    }


def _sx(v, bits):
    shift = 32 - bits
    return ((v << shift).astype(jnp.int32)) >> shift


def decode(instr):
    instr = instr.astype(jnp.uint32)
    i = instr.astype(jnp.int32)
    op = i & 0x7F
    rd = (i >> 7) & 31
    f3 = (i >> 12) & 7
    rs1 = (i >> 15) & 31
    rs2 = (i >> 20) & 31
    f7 = (jnp.right_shift(instr, jnp.uint32(25))).astype(jnp.int32) & 0x7F
    imm_i = _sx((jnp.right_shift(instr, jnp.uint32(20))).astype(jnp.int32) & 0xFFF, 12)
    imm_s = _sx(((f7 << 5) | rd), 12)
    imm_b = _sx(
        (((i >> 31) & 1) << 12)
        | (((i >> 7) & 1) << 11)
        | (((i >> 25) & 0x3F) << 5)
        | (((i >> 8) & 0xF) << 1),
        13,
    )
    imm_u = i & jnp.int32(0xFFFFF000 - (1 << 32) if False else -4096)  # mask upper 20 bits
    imm_u = jnp.bitwise_and(i, jnp.int32(-4096))
    imm_j = _sx(
        (((i >> 31) & 1) << 20)
        | (((i >> 12) & 0xFF) << 12)
        | (((i >> 20) & 1) << 11)
        | (((i >> 21) & 0x3FF) << 1),
        21,
    )
    return dict(op=op, rd=rd, f3=f3, rs1=rs1, rs2=rs2, f7=f7,
                imm_i=imm_i, imm_s=imm_s, imm_b=imm_b, imm_u=imm_u, imm_j=imm_j)


def execute(cpu, instr):
    """One architectural step (no memory access side effects).

    Returns (cpu', mem) where mem = dict(is_load, is_store, addr, st_data, rd)
    — the platform performs the access, adds its cycle cost, and writes the
    loaded value back via ``writeback``.
    """
    d = decode(instr)
    pc = cpu["pc"]
    regs = cpu["regs"]
    rs1v = regs[d["rs1"]]
    rs2v = regs[d["rs2"]]

    is_lui = d["op"] == isa.OP_LUI
    is_auipc = d["op"] == isa.OP_AUIPC
    is_jal = d["op"] == isa.OP_JAL
    is_jalr = d["op"] == isa.OP_JALR
    is_br = d["op"] == isa.OP_BRANCH
    is_load = d["op"] == isa.OP_LOAD
    is_store = d["op"] == isa.OP_STORE
    is_imm = d["op"] == isa.OP_IMM
    is_reg = d["op"] == isa.OP_REG

    is_sub = is_reg & (d["f7"] == 0b0100000)
    is_mul = is_reg & (d["f7"] == isa.F7_MULDIV)
    alu_rhs = jnp.where(is_imm, d["imm_i"], rs2v)
    alu = jnp.where(
        is_mul, rs1v * rs2v, jnp.where(is_sub, rs1v - rs2v, rs1v + alu_rhs)
    )

    taken = jnp.select(
        [d["f3"] == isa.F3_BEQ, d["f3"] == isa.F3_BNE, d["f3"] == isa.F3_BLT, d["f3"] == isa.F3_BGE],
        [rs1v == rs2v, rs1v != rs2v, rs1v < rs2v, rs1v >= rs2v],
        False,
    )

    next_pc = pc + 4
    next_pc = jnp.where(is_br & taken, pc + d["imm_b"], next_pc)
    next_pc = jnp.where(is_jal, pc + d["imm_j"], next_pc)
    next_pc = jnp.where(is_jalr, (rs1v + d["imm_i"]) & ~1, next_pc)

    wb = alu
    wb = jnp.where(is_lui, d["imm_u"], wb)
    wb = jnp.where(is_auipc, pc + d["imm_u"], wb)
    wb = jnp.where(is_jal | is_jalr, pc + 4, wb)
    do_wb = (is_lui | is_auipc | is_jal | is_jalr | is_imm | is_reg) & (d["rd"] != 0)

    regs = jnp.where(
        do_wb, regs.at[d["rd"]].set(wb), regs
    ) if False else regs.at[jnp.where(do_wb, d["rd"], 0)].set(jnp.where(do_wb, wb, regs[0]))
    regs = regs.at[0].set(0)  # x0 is hardwired

    halted = cpu["halted"] | (is_jal & (d["rd"] == 0) & (d["imm_j"] == 0))

    cpu = dict(cpu)
    cpu["regs"] = regs
    cpu["pc"] = jnp.where(halted, pc, next_pc)
    cpu["halted"] = halted
    cpu["instret"] = cpu["instret"] + (~halted).astype(jnp.int32)

    mem = {
        "is_load": is_load & ~halted,
        "is_store": is_store & ~halted,
        "addr": jnp.where(is_store, rs1v + d["imm_s"], rs1v + d["imm_i"]),
        "st_data": rs2v,
        "rd": d["rd"],
    }
    return cpu, mem


def writeback(cpu, rd, value):
    regs = cpu["regs"].at[jnp.where(rd != 0, rd, 0)].set(
        jnp.where(rd != 0, value, cpu["regs"][0])
    )
    cpu = dict(cpu)
    cpu["regs"] = regs.at[0].set(0)
    return cpu
