"""Benchmark workloads: the paper's Table III network layers as VMM jobs,
program generation for both execution modes, ``from_arch`` tiles that
map the assigned LM architectures' GEMMs onto 256×256 crossbars, and the
CPU side of hybrid dense+spiking jobs (the spike driver program).

Modes:
  riscv — nested-loop VMM on the DRAM-resident matrices, run by the CPU
          co-located with main memory (paper §V-B);
  cim   — offload: each managing CPU drives its two CIM-Units in a
          software-pipelined pair (stream j → unit0, stream j+1 → unit1,
          then drain both); inputs staged in local scratch, outputs DMA'd
          back by the units, O written to shared DRAM as posted writes;
  hybrid — the above runs concurrently with a spiking network whose input
          raster a second live CPU injects through tick-addressed
          CIM_REG_SPIKE stores (``spike_driver_program``), reading the
          output layer's spike counts back over the dense mailbox protocol
          (CIM_REG_COUNTS) and publishing them to shared DRAM.  Platform
          assembly lives in snn/topology.py (``build_hybrid``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import segmentation as seg
from repro.vp import isa
from repro.vp.assembler import vmm_riscv_program
from repro.vp.platform import SCRATCH_WORDS


@dataclasses.dataclass(frozen=True)
class Layer:
    network: str
    layer: str
    h: int
    w: int
    p: int

    @property
    def name(self):
        return f"{self.network}-{self.layer}"

    def scaled(self, f: int):
        # keep p >= 2 so multi-manager offload benchmarks stay loaded
        return Layer(self.network, self.layer, max(self.h // f, 4), max(self.w // f, 4), max(self.p // f, 2))


TABLE_III = [
    Layer("Googlenet", "conv1", 224, 224, 7),
    Layer("Googlenet", "conv2", 56, 56, 3),
    Layer("ImageNet", "conv1", 224, 224, 11),
    Layer("ImageNet", "conv2", 207, 207, 5),
    Layer("MobileNets", "conv1", 224, 224, 3),
    Layer("MobileNets", "conv2", 112, 112, 3),
]

A_BASE_W = 1024  # DRAM word offsets


def layer_data(layer: Layer, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-8, 8, (layer.h, layer.w), dtype=np.int32)
    b = rng.integers(-8, 8, (layer.w, layer.p), dtype=np.int32)
    o = a @ b
    return a, b, o


def dram_image(layer: Layer, a, b):
    a_base = A_BASE_W
    b_base = a_base + layer.h * layer.w
    o_base = b_base + layer.w * layer.p
    words = np.zeros(o_base + layer.h * layer.p, np.int32)
    words[a_base:b_base] = a.reshape(-1)
    words[b_base:o_base] = b.reshape(-1)
    return words, a_base * 4, b_base * 4, o_base * 4, o_base


def riscv_workload(layer: Layer, seed: int = 0):
    """Program + DRAM image for the RISC-V + main-memory mode (one CPU)."""
    a, b, o = layer_data(layer, seed)
    words, a_b, b_b, o_b, o_w = dram_image(layer, a, b)
    prog = vmm_riscv_program(layer.h, layer.w, layer.p, a_b, b_b, o_b)
    return {"programs": {0: prog}, "dram": words, "expected": o, "o_word": o_w}


def cim_pair_program(cim_bases, h, w, p_lo, p_hi, o_base, p_total, in_res=8, out_res=8,
                     ordinals=(0, 1)):
    """Manager-CPU program driving two CIM units over vectors [p_lo, p_hi).

    ``ordinals``: the two units' mailbox ordinals in the manager's scratch
    (segmentation.mailbox_ordinals) — flag word = ordinal, output area =
    OUT0 + ordinal*256.
    """
    cfg = (h & 0x1FF) | (w & 0x1FF) << 9 | (in_res & 0xF) << 18 | (out_res & 0xF) << 22
    sb = isa.SCRATCH_BASE
    bs = sb + seg.B_STAGE * 4
    f0, f1 = ordinals[0] * 4, ordinals[1] * 4
    out0 = (seg.OUT0 + ordinals[0] * 256) * 4
    out1 = (seg.OUT0 + ordinals[1] * 256) * 4
    src = [
        f"    li s0, {cim_bases[0]}",
        f"    li s1, {cim_bases[1]}",
        f"    li t0, {cfg}",
        f"    sw t0, {isa.CIM_REG_CONFIG}(s0)",
        f"    sw t0, {isa.CIM_REG_CONFIG}(s1)",
        f"    li s2, 0",  # j_local
        f"    li s3, {p_hi - p_lo}",  # nj
        "pair_loop:",
        f"    li t0, {sb}",
        f"    sw zero, {f0}(t0)",
        f"    sw zero, {f1}(t0)",
        # ---- stream vector j -> unit 0
        f"    li t2, {w}",
        "    mul t3, s2, t2",
        "    add t3, t3, t3",
        "    add t3, t3, t3",
        f"    li t5, {bs}",
        "    add t3, t3, t5",
        "    li t4, 0",
        "in0:",
        "    lw t1, 0(t3)",
        f"    sw t1, {isa.CIM_REG_INPUT}(s0)",
        "    addi t3, t3, 4",
        "    addi t4, t4, 1",
        "    blt t4, t2, in0",
        f"    sw zero, {isa.CIM_REG_START}(s0)",
        # ---- stream vector j+1 -> unit 1 (if any)
        "    addi t6, s2, 1",
        "    bge t6, s3, drain0",
        "    mul t3, t6, t2",
        "    add t3, t3, t3",
        "    add t3, t3, t3",
        f"    li t5, {bs}",
        "    add t3, t3, t5",
        "    li t4, 0",
        "in1:",
        "    lw t1, 0(t3)",
        f"    sw t1, {isa.CIM_REG_INPUT}(s1)",
        "    addi t3, t3, 4",
        "    addi t4, t4, 1",
        "    blt t4, t2, in1",
        f"    sw zero, {isa.CIM_REG_START}(s1)",
        # ---- drain unit 0: poll flag, copy outputs to O[:, p_lo + j]
        "drain0:",
        f"    li t0, {sb}",
        "poll0:",
        f"    lw t1, {f0}(t0)",
        "    beq t1, zero, poll0",
        f"    li t3, {sb + out0}",  # src in scratch
        f"    addi t5, s2, {p_lo}",  # global j
        "    add t5, t5, t5",
        "    add t5, t5, t5",  # j*4
        f"    li t1, {o_base}",
        "    add t5, t5, t1",  # &O[0, j]
        "    li t4, 0",
        f"    li t2, {h}",
        "out0:",
        "    lw t1, 0(t3)",
        "    sw t1, 0(t5)",
        "    addi t3, t3, 4",
        f"    addi t5, t5, {4 * p_total}",  # O row stride
        "    addi t4, t4, 1",
        "    blt t4, t2, out0",
        # ---- drain unit 1 (if started)
        "    addi t6, s2, 1",
        "    bge t6, s3, next_pair",
        f"    li t0, {sb}",
        "poll1:",
        f"    lw t1, {f1}(t0)",
        "    beq t1, zero, poll1",
        f"    li t3, {sb + out1}",
        f"    addi t5, t6, {p_lo}",
        "    add t5, t5, t5",
        "    add t5, t5, t5",
        f"    li t1, {o_base}",
        "    add t5, t5, t1",
        "    li t4, 0",
        f"    li t2, {h}",
        "out1:",
        "    lw t1, 0(t3)",
        "    sw t1, 0(t5)",
        "    addi t3, t3, 4",
        f"    addi t5, t5, {4 * p_total}",
        "    addi t4, t4, 1",
        "    blt t4, t2, out1",
        "next_pair:",
        "    addi s2, s2, 2",
        f"    li t2, {w}",  # restore w bound (clobbered)
        "    blt s2, s3, pair_loop",
        "    halt",
    ]
    return "\n".join(src)


def cim_workload(layer: Layer, mgr_segments, cim_ids_per_mgr, seed: int = 0, ordinals=None):
    """Programs + crossbar/scratch/DRAM images for offload mode.

    mgr_segments: list of CPU segment ids driving CIM pairs
    cim_ids_per_mgr: {mgr_seg: (global_cim_id0, global_cim_id1)}
    Vectors are split contiguously across managers.
    """
    a, b, o = layer_data(layer, seed)
    words, a_b, b_b, o_b, o_w = dram_image(layer, a, b)
    n_mgr = len(mgr_segments)
    per = -(-layer.p // n_mgr)
    programs, crossbars, scratch = {}, {}, {}
    for mi, m in enumerate(mgr_segments):
        p_lo, p_hi = mi * per, min((mi + 1) * per, layer.p)
        if p_lo >= p_hi:
            continue
        g0, g1 = cim_ids_per_mgr[m]
        crossbars[g0] = a.astype(np.int8)
        crossbars[g1] = a.astype(np.int8)
        bases = (seg.cim_global_base(g0), seg.cim_global_base(g1))
        ords = ((ordinals or {}).get(g0, 0), (ordinals or {}).get(g1, 1))
        programs[m] = cim_pair_program(
            bases, layer.h, layer.w, p_lo, p_hi, o_b, layer.p, ordinals=ords
        )
        # stage this manager's input vectors (column-major by local j)
        bl = np.ascontiguousarray(b[:, p_lo:p_hi].T).reshape(-1)  # (nj, w)
        scratch[m] = {seg.B_STAGE: bl.astype(np.int32)}
    return {
        "programs": programs,
        "dram": words,
        "crossbars": crossbars,
        "scratch": scratch,
        "expected": o,
        "o_word": o_w,
    }




# ---------------------------------------------------------------------------
# hybrid dense+spiking: the spike driver CPU's side

# scratch word offset of the staged spike-event table — above the manager
# mailbox OUT areas (segmentation.OUT0 + ordinal*256, ordinal <= 6), below
# the scratch top; holds up to SCRATCH_WORDS - EV_TABLE events
EV_TABLE = 2048


def spike_events(raster):
    """Raster -> CIM_REG_SPIKE store words in timestep order.

    One word per spike, ``isa.pack_spike(timestep, axon)``; the driver
    program sends one spike per store, so the raster must be 0/1 (which is
    what ``snn.rate_encode`` produces)."""
    raster = np.asarray(raster)
    assert raster.min(initial=0) >= 0 and raster.max(initial=0) <= 1, \
        "CPU spike injection sends one spike per store: raster must be 0/1"
    ts, axons = np.nonzero(raster)  # row-major: timestep order, the contract
    assert len(ts) == 0 or (ts.max() < (1 << 15) and axons.max() < (1 << 16))
    return np.array([isa.pack_spike(int(t), int(a)) for t, a in zip(ts, axons)],
                    np.int32)


def injection_cycles_bound(n_events: int) -> int:
    """Conservative upper bound on the driver program's injection-loop
    cycles from t=0 (loop body: scratch load, MMIO post, two addi, branch —
    ~7 cycles plus icache-miss amortization; 16 is generous).
    ``build_hybrid`` sizes ``tick_period`` with this so every tick-k store
    retires before (k+1)*tick_period — the CIM_REG_SPIKE deadline contract,
    policed at runtime by the ``snn_mmio_late`` watermark."""
    return 64 + 16 * n_events


def spike_driver_program(in_base, out_base, n_events, n_ticks, n_out,
                         out_ordinal, counts_base):
    """The hybrid job's spike-side CPU program (the paper's host control
    path next to the accelerators):

    1. stream the staged event table (scratch, ``EV_TABLE``) into the input
       unit's ``CIM_REG_SPIKE`` — tick-addressed AER injection, concurrent
       with whatever the dense managers are doing;
    2. request the output unit's spike counts as of tick ``n_ticks``
       (``CIM_REG_COUNTS``) and poll the mailbox flag, exactly like a dense
       manager polls an OP completion;
    3. copy the DMA'd counts from scratch to shared DRAM at ``counts_base``
       (posted remote writes), then halt.
    """
    sb = isa.SCRATCH_BASE
    flag = out_ordinal * 4
    out_area = sb + (seg.OUT0 + out_ordinal * 256) * 4
    src = [
        f"    li s0, {in_base}",
        f"    li s1, {sb + EV_TABLE * 4}",
        f"    li s2, {n_events}",
        "    li s3, 0",
        "    beq s2, zero, req",
        "inj:",
        "    lw t1, 0(s1)",
        f"    sw t1, {isa.CIM_REG_SPIKE}(s0)",
        "    addi s1, s1, 4",
        "    addi s3, s3, 1",
        "    blt s3, s2, inj",
        "req:",
        f"    li t0, {sb}",
        f"    sw zero, {flag}(t0)",
        f"    li s4, {out_base}",
        f"    li t1, {n_ticks}",
        f"    sw t1, {isa.CIM_REG_COUNTS}(s4)",
        "poll:",
        f"    lw t1, {flag}(t0)",
        "    beq t1, zero, poll",
        f"    li s1, {out_area}",
        f"    li s2, {counts_base}",
        "    li s3, 0",
        f"    li t2, {n_out}",
        "copy:",
        "    lw t1, 0(s1)",
        "    sw t1, 0(s2)",
        "    addi s1, s1, 4",
        "    addi s2, s2, 4",
        "    addi s3, s3, 1",
        "    blt s3, t2, copy",
        "    halt",
    ]
    return "\n".join(src)


def from_arch(arch: str, max_tiles: int = 8):
    """Tile an assigned LM architecture's FFN GEMM onto 256×256 crossbars —
    the paper's benchmark methodology applied to this framework's models."""
    from repro.configs import get_config

    cfg = get_config(arch)
    d = cfg.d_model
    f = cfg.d_ff or (cfg.ssm.expand * d if cfg.ssm else d)
    tiles_r = -(-min(d, 1024) // 256)
    tiles_c = -(-min(f, 1024) // 256)
    layers = []
    for r in range(min(tiles_r, max_tiles)):
        for c in range(min(tiles_c, max_tiles // max(tiles_r, 1) or 1)):
            layers.append(Layer(arch, f"ffn_tile_{r}_{c}", 256, 256, 8))
    return layers[:max_tiles]
