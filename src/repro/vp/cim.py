"""Memristor-based CIM-Unit: controller FSM (IDLE→IN→OP→OUT), micro-engine
register file, and the crossbar calculator (quantized VMM).

The paper's CIM-Unit [13] couples a mixed-signal "calculator" (crossbar +
DAC/ADC/S+H) with a digital micro-engine.  TPU adaptation (DESIGN.md §2):
the analog bit-serial crossbar becomes a bit-sliced integer VMM
(kernels/crossbar_vmm) with identical finite-resolution numerics; the FSM
timing model is kept:

  IN  cycles = ceil(w · in_res / PORT_BITS)   (input streaming, §III-B)
  OP  cycles = in_res · OP_CYCLE + ADC_LAT    (bit-serial drive + conversion)
  OUT cycles = h · out_res / PORT_BITS        (result streaming)

Within a segment step the FSM is event-driven by MMIO messages; the actual
VMM math of every unit that finished its OP phase during the quantum runs
*batched at the quantum boundary* (a masked 256×256 matvec per unit) — legal
because results are only observable after ``busy_until``, and TPU-friendly
because the "analog" compute becomes one dense batched matmul.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.vp import isa

XBAR = 256  # crossbar dimension (Table II: 256×256)
PORT_BITS = 32
OP_CYCLE = 2
ADC_LAT = 16


@dataclasses.dataclass(frozen=True)
class CIMParams:
    n_slots: int = 2  # CIM units per segment (Table II: 2 × segment)
    in_res: int = 8
    out_res: int = 8
    w_res: int = 8


def cim_state(n_slots: int, snn_fanout: int = 1):
    z = lambda *s, dt=jnp.int32: jnp.zeros(s, dt)
    return {
        "present": jnp.zeros((n_slots,), jnp.bool_),
        "state": z(n_slots),
        "rows": z(n_slots),
        "cols": z(n_slots),
        "in_res": jnp.full((n_slots,), 8, jnp.int32),
        "out_res": jnp.full((n_slots,), 8, jnp.int32),
        "weights": z(n_slots, XBAR, XBAR, dt=jnp.int8),
        "wrow": z(n_slots),
        "in_buf": z(n_slots, XBAR),
        "in_count": z(n_slots),
        "out_buf": z(n_slots, XBAR),
        "busy_until": z(n_slots),
        "op_done_at": jnp.full((n_slots,), -1, jnp.int32),
        "ops": z(n_slots),
        # wiring: manager segment + scratch addresses for DMA writeback
        "mgr_seg": z(n_slots),
        "flag_addr": z(n_slots),
        "out_addr": z(n_slots),
        # --- spike (LIF) mode: crossbar as synapse matrix (snn/) ---
        # in_buf doubles as the per-tick spike-count accumulator; rows/cols
        # are neuron/axon counts; weights are the synapse conductances.
        "mode": z(n_slots),  # isa.CIM_MODE_DENSE / CIM_MODE_SPIKE
        "v": z(n_slots, XBAR),  # membrane potentials
        "refrac": z(n_slots, XBAR),  # refractory countdown per neuron
        "thresh": jnp.ones((n_slots,), jnp.int32),
        "leak": z(n_slots),
        "refrac_period": z(n_slots),
        "tick_period": z(n_slots),  # SNN tick pitch (0 = never ticks)
        "next_tick": z(n_slots),  # sim time of the next scheduled tick
        # bounded-horizon gate for cyclic nets (0 = unlimited): a unit whose
        # ``ticks`` counter reaches tick_limit stops ticking forever, and
        # spikes addressed to ticks past the horizon are consumed + dropped
        # (vp/platform.py) — recurrent/lateral connectivity can self-sustain
        # indefinitely, so termination needs an explicit tick horizon that
        # the cycle-aware oracle (snn/workloads.py) shares exactly.
        "tick_limit": z(n_slots),
        # AER fan-out table, one row per destination (wide layers fan a
        # stripe's spikes out to every downstream shard): neuron rows in
        # [row_lo, row_hi) route to (dst_seg, dst_slot) at axon
        # axon_base + row.  dst_seg -1 = unused entry (all -1 = sink).
        "dst_seg": jnp.full((n_slots, snn_fanout), -1, jnp.int32),
        "dst_slot": z(n_slots, snn_fanout),
        "axon_base": z(n_slots, snn_fanout),
        "row_lo": z(n_slots, snn_fanout),
        "row_hi": jnp.full((n_slots, snn_fanout), XBAR, jnp.int32),
        # column-tile wiring: slot index of the stripe owner this tile
        # forwards its synaptic charge to at tick time (self = owner).
        # Contributor tiles hold no neurons (rows == 0, membrane pinned 0).
        "owner_slot": jnp.arange(n_slots, dtype=jnp.int32),
        "spike_counts": z(n_slots, XBAR),  # emitted spikes per neuron
        "spikes_total": z(n_slots),
        # consumed-side twin of spikes_total: AER events this unit actually
        # integrated (vp/platform._apply_inbox) — the per-tile consumed
        # spike rate obs/metrics.py and snn.consumed_rates report, feeding
        # overlap-aware traffic matrices (ROADMAP item 2)
        "spikes_in": z(n_slots),
        "ticks": z(n_slots),
        # pending spike-count readback request (CIM_REG_COUNTS): the target
        # tick count, or -1 for none.  Served at the quantum boundary once
        # ``ticks`` reaches the target (or the unit can never tick again) by
        # DMA-ing spike_counts to the manager mailbox — the spiking analogue
        # of dense OUT-phase writeback (vp/platform.py).  A pending request
        # keeps the unit busy for the termination reducer, so a simulation
        # never ends with an unanswered readback.
        "count_req": jnp.full((n_slots,), -1, jnp.int32),
    }


def apply_config(cims, u, value, t_now):
    cims = dict(cims)
    cims["rows"] = cims["rows"].at[u].set(value & 0x1FF)  # 9 bits: up to 256
    cims["cols"] = cims["cols"].at[u].set((value >> 9) & 0x1FF)
    cims["in_res"] = cims["in_res"].at[u].set((value >> 18) & 0xF)
    cims["out_res"] = cims["out_res"].at[u].set((value >> 22) & 0xF)
    cims["state"] = cims["state"].at[u].set(isa.CIM_ST_IN)  # ready for input
    cims["in_count"] = cims["in_count"].at[u].set(0)
    return cims


def apply_mode(cims, u, value):
    """CIM_REG_MODE write: {mode[0], thresh[16:1], leak[24:17], refrac[28:25]}."""
    cims = dict(cims)
    cims["mode"] = cims["mode"].at[u].set(value & 1)
    cims["thresh"] = cims["thresh"].at[u].set(jnp.maximum((value >> 1) & 0xFFFF, 1))
    cims["leak"] = cims["leak"].at[u].set((value >> 17) & 0xFF)
    cims["refrac_period"] = cims["refrac_period"].at[u].set((value >> 25) & 0xF)
    return cims


def apply_input(cims, u, value):
    cims = dict(cims)
    idx = cims["in_count"][u]
    cims["in_buf"] = cims["in_buf"].at[u, jnp.clip(idx, 0, XBAR - 1)].set(value)
    cims["in_count"] = cims["in_count"].at[u].add(1)
    return cims


def apply_start(cims, u, t_now):
    """Launch OP: busy_until = now + IN-residual + OP cycles."""
    cims = dict(cims)
    w = cims["cols"][u]
    h = cims["rows"][u]
    in_cyc = (w * cims["in_res"][u] + PORT_BITS - 1) // PORT_BITS
    op_cyc = cims["in_res"][u] * OP_CYCLE + ADC_LAT
    out_cyc = (h * cims["out_res"][u] + PORT_BITS - 1) // PORT_BITS
    done = t_now + in_cyc + op_cyc + out_cyc
    cims["state"] = cims["state"].at[u].set(isa.CIM_ST_OP)
    cims["busy_until"] = cims["busy_until"].at[u].set(done)
    return cims


def crossbar_vmm_ref(weights, x, in_res, out_res, f_and=None, f_xor=None):
    """Quantized crossbar VMM (jnp oracle; the Pallas kernel mirrors this).

    weights int8 (R, C); x int32 (C,) — DAC clamps x to in_res signed bits,
    analog MAC is exact, ADC saturates the result to out_res+acc headroom.
    ``f_and``/``f_xor`` (int8 (R, C), optional, repro.faults): read-time
    crossbar fault masks — the MAC contracts ``(w & f_and) ^ f_xor``.
    """
    if f_and is not None:
        weights = (weights & f_and) ^ f_xor
    lo_in = -(1 << (in_res - 1))
    hi_in = (1 << (in_res - 1)) - 1
    xq = jnp.clip(x, lo_in, hi_in)
    acc = weights.astype(jnp.int32) @ xq
    # ADC with fixed full-scale: saturate to out_res-bit signed range scaled
    # by the crossbar accumulation headroom (log2(C) extra bits)
    hi_out = (1 << (out_res - 1 + 8)) - 1
    return jnp.clip(acc, -hi_out - 1, hi_out)


def finish_ops(cims, t_end, use_kernel: bool = False):
    """Batched quantum-boundary completion: every unit whose OP finishes by
    t_end computes its VMM and transitions to OUT.  Returns (cims, done_mask).
    """
    done = (
        cims["present"]
        & (cims["state"] == isa.CIM_ST_OP)
        & (cims["busy_until"] <= t_end)
    )
    # crossbar fault masks (repro.faults): present in the state exactly
    # when the build carried crossbar faults — a static dict-key check, so
    # the fault-free step compiles identically to a pre-fault build
    fa, fx = cims.get("f_and"), cims.get("f_xor")
    if use_kernel:
        from repro.kernels.crossbar_vmm.ops import crossbar_vmm_batch

        # kernel block shapes specialize on the resolutions (static); the
        # platform runs the Table II configuration (8-bit I/O)
        outs = crossbar_vmm_batch(cims["weights"], cims["in_buf"], 8, 8,
                                  fa, fx)
    else:
        outs = jax.vmap(crossbar_vmm_ref, in_axes=(0, 0, None, None))(
            cims["weights"] if fa is None else (cims["weights"] & fa) ^ fx,
            cims["in_buf"], 8, 8
        )
    cims = dict(cims)
    cims["out_buf"] = jnp.where(done[:, None], outs, cims["out_buf"])
    # outputs ship by DMA in the same boundary step, so OUT completes
    # immediately and the FSM returns to IDLE (OUT-phase cycles are already
    # charged inside busy_until)
    cims["state"] = jnp.where(done, isa.CIM_ST_IDLE, cims["state"])
    cims["op_done_at"] = jnp.where(done, cims["busy_until"], cims["op_done_at"])
    cims["ops"] = cims["ops"] + done.astype(jnp.int32)
    cims["in_count"] = jnp.where(done, 0, cims["in_count"])
    return cims, done


def snn_tick(cims, t_gate, use_kernel: bool = False, grouped: bool = False):
    """Quantum-boundary LIF tick for spike-mode units (snn/ subsystem).

    A unit fires its tick at scheduled time T = ``next_tick`` once
    ``t_gate`` (the segment time at which this round's inbox was applied)
    has passed T + tick_period.  That one-period guard makes tick-k firing
    wait until every peer has certifiably emitted its tick-(k-1) spikes:
    the controller's decoupling bound gives t_peer >= t_gate - latency >=
    T + tick_period - latency >= T (builder contract: tick_period >=
    channel latency), and an emitted spike needs exactly one routing round
    to reach pending.  One tick per quantum; segment time advances at most
    one channel latency per round (monotone min-peer bound), so ticks are
    never skipped.  Bit-identical across all controller backends and all
    segmentations by construction.  The guard is direction-agnostic: a
    fan-out entry may target a *later* layer, the unit's own layer
    (lateral), or an *earlier* one (recurrent feedback) — in every case a
    spike emitted at tick k integrates at the destination's tick k+1, so
    cyclic nets keep the same one-tick-per-hop delay semantics and the
    same bit-exactness argument (snn/topology.py).

    ``grouped`` (static; cfg.snn_grouped) enables multi-crossbar layers:
    a neuron stripe whose fan-in exceeds one crossbar's columns occupies a
    *column group* of co-located slots — the owner holds the membrane
    state, contributor tiles hold column slices of the synapse matrix and
    forward their charge (an exact int32 partial contraction) to the owner
    within the same tick.  Co-location in one segment is what makes the
    reduction tick-atomic: every member sees the same t_gate, so the group
    fires in lockstep and the summed charge equals the unsharded
    contraction bit-for-bit.

    Returns (cims', fired_rows bool (U, XBAR), fired bool (U,),
    tick_time (U,)) — the platform turns fired rows into AER MSG_SPIKE
    events (or spike_counts for sink units) stamped at the tick time.
    """
    fire = (
        cims["present"]
        & (cims["mode"] == isa.CIM_MODE_SPIKE)
        & (cims["tick_period"] > 0)
        & (t_gate >= cims["next_tick"] + cims["tick_period"])
        # bounded horizon (cyclic nets): tick_limit > 0 caps the unit at
        # exactly tick_limit ticks — ticks 0..tick_limit-1 fire, then the
        # unit is quiescent forever (recurrent activity need not die out,
        # so the horizon is what makes termination decidable)
        & ((cims["tick_limit"] == 0) | (cims["ticks"] < cims["tick_limit"]))
    )
    # fault-injection inputs (repro.faults): static dict-key checks — the
    # arrays exist exactly when the build carried that fault family, so
    # the fault-free tick compiles identically to a pre-fault build
    fa, fx = cims.get("f_and"), cims.get("f_xor")
    dead, dth = cims.get("f_dead"), cims.get("f_dth")
    is_contrib = None
    if grouped:
        from repro.kernels.lif_step import ref as lif_ref

        n_slots = cims["present"].shape[0]
        is_contrib = cims["owner_slot"] != jnp.arange(n_slots)
        # contributor tiles flush their charge only on a firing tick (the
        # whole group fires in lockstep: same segment, same wiring)
        fwd = is_contrib & fire
        charge = jax.vmap(lif_ref.syn_charge)(cims["weights"],
                                              cims["in_buf"], fa, fx)
        extra = jnp.zeros_like(charge).at[
            jnp.where(fwd, cims["owner_slot"], n_slots)
        ].add(jnp.where(fwd[:, None], charge, 0), mode="drop")
        if use_kernel:
            # the fused kernel redoes the local contraction on the MXU (the
            # fp32 result is bit-equal to the int32 ``charge``); merging the
            # group happens through its extra-charge input
            from repro.kernels.lif_step.ops import lif_step_units

            v2, refrac2, fired_i = lif_step_units(
                cims["weights"], cims["in_buf"], cims["v"], cims["refrac"],
                cims["thresh"], cims["leak"], cims["refrac_period"], extra,
                fa, fx, dead, dth,
            )
        else:
            # charge is already in hand for every slot: run only the
            # post-contraction LIF stages on the group-summed charge
            v2, refrac2, fired_i = jax.vmap(lif_ref.lif_update)(
                charge + extra, cims["v"], cims["refrac"],
                cims["thresh"], cims["leak"], cims["refrac_period"],
                dead, dth,
            )
    else:
        if use_kernel:
            from repro.kernels.lif_step.ops import lif_step_units
        else:
            from repro.kernels.lif_step.ref import lif_step_units
        v2, refrac2, fired_i = lif_step_units(
            cims["weights"], cims["in_buf"], cims["v"], cims["refrac"],
            cims["thresh"], cims["leak"], cims["refrac_period"],
            None, fa, fx, dead, dth,
        )
    rows_idx = jnp.arange(XBAR)
    fired_rows = fire[:, None] & (fired_i != 0) & (rows_idx[None, :] < cims["rows"][:, None])
    cims = dict(cims)
    sel = lambda new, old: jnp.where(fire[:, None], new, old)
    cims["v"] = sel(v2, cims["v"])
    cims["refrac"] = sel(refrac2, cims["refrac"])
    if grouped:
        # contributor tiles hold no neurons — their lanes ran the fused
        # update on a meaningless local contraction; pin membrane state to
        # zero so termination checks and readback never see ghost charge
        cims["v"] = jnp.where(is_contrib[:, None], 0, cims["v"])
        cims["refrac"] = jnp.where(is_contrib[:, None], 0, cims["refrac"])
    cims["in_buf"] = jnp.where(fire[:, None], 0, cims["in_buf"])
    tick_time = cims["next_tick"]
    cims["next_tick"] = cims["next_tick"] + jnp.where(fire, cims["tick_period"], 0)
    cims["spike_counts"] = cims["spike_counts"] + fired_rows.astype(jnp.int32)
    cims["spikes_total"] = cims["spikes_total"] + fired_rows.sum(-1).astype(jnp.int32)
    cims["ticks"] = cims["ticks"] + fire.astype(jnp.int32)
    return cims, fired_rows, fire, tick_time
