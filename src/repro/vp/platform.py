"""Virtual-platform assembly: segments of {RISC-V CPU, L1 caches, scratch
SRAM, shared DRAM, CIM units} + the per-quantum segment step.

The step is a *pure function* ``(seg_state, pending_inbox, quantum_instrs,
t_limit) → (seg_state', outbox, pending')`` — branchless inside, so the same
compiled body runs one segment (sequential backend), all segments vectorized
(vmap) or one-segment-per-device (shard_map).  See core/controller.py.

Flow per quantum (paper Fig. 2/3):
  1. apply pending inbox messages whose ``t_avail <= local time``
     (ordered by arrival slot; CIM INPUT streams keep ordering via ranked
     scatter);
  2. run up to N instruction slots on the CPU (each costs its modeled
     cycles; execution gates on ``time < t_limit``, the controller's
     decoupling bound);
  3. quantum-boundary CIM completion: every unit whose OP finished computes
     its crossbar VMM (batched) and DMAs outputs + a done-flag to its
     manager segment's scratch via channel messages;
  4. quantum-boundary SNN work (spike-mode units): the LIF tick when due,
     then service of pending spike-count readbacks (CIM_REG_COUNTS) over
     the same manager-mailbox DMA protocol — hybrid jobs' CPUs poll the
     flag word exactly like dense completions.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as ch
from repro.vp import cim as cim_mod
from repro.vp import isa, memory, riscv

PROG_WORDS = 512
OUT_CAP = 4096
IN_CAP = 4096
STORE_LOG = 2048  # max local-DRAM stores per quantum
DRAM_BACKING = 1 << 20  # words
SCRATCH_WORDS = 1 << 12


@dataclasses.dataclass(frozen=True)
class VPConfig:
    n_segments: int
    n_cim_slots: int = 2
    dram_segment: int = 0
    timing: memory.Timing = memory.Timing()
    channel_latency: int = 10_000  # cycles; >= quantum (paper's rule)
    local_latency: int = 64  # intra-segment device message latency
    use_kernel: bool = False  # crossbar via Pallas kernel vs jnp ref
    # channel-box capacities: the worst-case defaults are generous, but every
    # message lane is touched every round (inbox masks, routing scatters,
    # merge packs), so on small platforms the caps *are* the round cost.
    # Builders may right-size them per workload — undersizing is always loud,
    # never silent: the sticky watermarks raise past-cap (controller checks),
    # and results are bit-identical across cap choices that don't overflow.
    in_cap: int = IN_CAP
    out_cap: int = OUT_CAP
    store_log: int = STORE_LOG  # max local-DRAM stores per quantum
    has_cpu: bool = True  # any CPU that can ever execute (present + program);
                          # False statically drops the instruction-slot scan
                          # and the DRAM store log from the step — a
                          # build-time-halted CPU can never un-halt, so the
                          # scan is provably dead (bit-identical) without it
    has_snn: bool = False  # any spike-mode unit wired at build time; gates
                           # the per-quantum LIF tick so dense-only builds
                           # never pay the batched synapse contraction
    snn_fanout: int = 1  # AER fan-out table entries per unit (wide layers
                         # route a stripe's spikes to several downstream
                         # shards); sized by the builder from the wiring
    snn_grouped: bool = False  # any multi-crossbar column group wired; gates
                               # the tick-time charge reduction (cim.snn_tick)
    snn_tick_period: int = 0  # the platform's global LIF tick pitch (0 = no
                              # ticking spike-mode unit wired).  Static wiring
                              # like cim_seg: the builder asserts every ticking
                              # unit shares it, because CPU spike injection
                              # (CIM_REG_SPIKE) is *tick-addressed* — the store
                              # names a tick k and the platform pins the
                              # resulting MSG_SPIKE's t_avail to the grid time
                              # (k+1)*period, making injected spikes land in
                              # the same bucket as pre-scheduled raster events
                              # under every placement, backend, and quantum.
    # seeded fault-injection model (faults.FaultConfig) or None.  Static
    # like obs: the frozen config keys the controller's function cache and
    # every injection branch below is resolved at trace time — None
    # compiles the whole fault subsystem out of the step (bit-identical to
    # a build that predates it).
    faults: object = None
    # static wiring: global cim id -> (segment, slot); manager cpu segment
    cim_seg: tuple = ()
    cim_slot: tuple = ()

    def latency_matrix(self):
        s = self.n_segments
        lat = np.full((s, s), self.channel_latency, np.int32)
        np.fill_diagonal(lat, self.local_latency)
        return jnp.asarray(lat)


def segment_state(cfg: VPConfig):
    """One segment's zero state (stack n of these for the simulation)."""
    fc = cfg.faults
    state = {
        "time": jnp.zeros((), jnp.int32),
        "seg_id": jnp.zeros((), jnp.int32),
        "cpu": riscv.cpu_state(),
        "prog": jnp.zeros((PROG_WORDS,), jnp.uint32),
        "icache": memory.cache_state(memory.Timing().icache_sets),
        "dcache": memory.cache_state(memory.Timing().dcache_sets),
        "dram": memory.dram_state(DRAM_BACKING),
        "dram_present": jnp.zeros((), jnp.bool_),
        "scratch": jnp.zeros((SCRATCH_WORDS,), jnp.int32),
        "cims": cim_mod.cim_state(cfg.n_cim_slots, cfg.snn_fanout),
        "stats": {
            "instrs": jnp.zeros((), jnp.int32),
            "msgs": jnp.zeros((), jnp.int32),
            "outbox_peak": jnp.zeros((), jnp.int32),  # overflow sentinel
            "store_peak": jnp.zeros((), jnp.int32),  # store-log sentinel
            # sticky count of hybrid MMIO ops that violated their tick-grid
            # deadline: a CIM_REG_SPIKE store executed at/after its target
            # tick's grid time, or a CIM_REG_COUNTS readback served after the
            # unit had ticked past the requested count.  Either is
            # timing-dependent (round/quantum-sensitive), so the controller
            # raises loudly instead of returning placement-dependent results.
            "snn_mmio_late": jnp.zeros((), jnp.int32),
            # AER spike events this segment's units actually integrated —
            # the consumed side of the spike traffic (emitted side lives in
            # cims["spikes_total"]); surfaced by obs/metrics.py
            "spikes_consumed": jnp.zeros((), jnp.int32),
            "txn_hist": jnp.zeros((8,), jnp.int32),  # Fig. 1a trace histogram
        },
    }
    if fc is not None:
        # fault-state arrays exist exactly when the corresponding fault
        # family is active — absent keys keep the fault-off tree (and the
        # compiled step) byte-identical to a pre-fault build
        n = cfg.n_cim_slots
        xb = cim_mod.XBAR
        cims = dict(state["cims"])
        if fc.has_xbar_faults:
            # read-time crossbar masks: w_eff = (w & f_and) ^ f_xor — the
            # builder (core/segmentation.py) fills the fault sites per unit
            cims["f_and"] = jnp.full((n, xb, xb), -1, jnp.int8)
            cims["f_xor"] = jnp.zeros((n, xb, xb), jnp.int8)
        if fc.has_neuron_faults:
            cims["f_dead"] = jnp.zeros((n, xb), jnp.bool_)
            cims["f_dth"] = jnp.zeros((n, xb), jnp.int32)
        if fc.has_transport_faults:
            # placement-invariant unit identities: the transport hash keys
            # on these, never on (segment, slot), so re-segmenting the same
            # network drops the same spikes
            cims["f_uid"] = jnp.arange(n, dtype=jnp.int32)
            # the fault PRNG state rides the megaloop carry: the seed lives
            # on device so injection decisions never touch the host
            state["faults"] = {
                "seed": jnp.full((), fc.seed & 0xFFFFFFFF, jnp.uint32)}
            stats = dict(state["stats"])
            stats["spikes_dropped"] = jnp.zeros((), jnp.int32)
            stats["spikes_duped"] = jnp.zeros((), jnp.int32)
            state["stats"] = stats
        if fc.drop_overflow:
            # graceful degradation: outbox messages lost to truncation are
            # counted here instead of aborting the run (inbox losses live
            # in pending["lost_total"])
            stats = dict(state["stats"])
            stats["outbox_lost"] = jnp.zeros((), jnp.int32)
            state["stats"] = stats
        state["cims"] = cims
    return state


# ---------------------------------------------------------------------------
# inbox application


def _apply_inbox(cfg: VPConfig, st, pending):
    """Apply messages with t_avail <= time; return
    ``(st, pending', responses, has_resp, consumed)`` — ``consumed`` is the
    number of inbox messages this application retired (obs EV_ROUTE).

    AER spikes (MSG_SPIKE) are the exception to the arrival-time rule: a
    spike addressed to slot u integrates at u's next tick, so it is
    consumed when ``t_avail <= next_tick[u]`` — possibly before local time
    reaches t_avail, never after the tick it belongs to.  Spikes for later
    ticks stay pending.
    """
    t = st["time"]
    kind, addr, data = pending["kind"], pending["addr"], pending["data"]
    m = pending["valid"] & (pending["t_avail"] <= t)
    if cfg.has_snn:
        m = m & (kind != ch.MSG_SPIKE)
    # else: no spike-mode units exist, so any stray MSG_SPIKE just drains
    # through m (no handler matches kind 5) instead of pending forever

    cims = st["cims"]
    scratch = st["scratch"]
    dram = st["dram"]
    if cfg.has_cpu:
        # --- scratch DMA writes (masked lanes scatter out-of-bounds ->
        # dropped; NEVER write a "dead slot" with the old value: duplicate
        # scatter indices with different values are nondeterministic in
        # XLA).  The whole MMIO/DMA block is statically dead on a CPU-free
        # platform (VPConfig.has_cpu): every one of these kinds originates
        # from a CPU store or a CIM OP a CPU started, so only MSG_SPIKE can
        # ever circulate — stray other kinds drain without effect below. ---
        ms = m & (kind == ch.MSG_W_SCRATCH)
        sc_idx = jnp.clip(addr, 0, SCRATCH_WORDS - 1)
        scratch = st["scratch"].at[jnp.where(ms, sc_idx, SCRATCH_WORDS)].set(data, mode="drop")

        # --- DRAM posted writes ---
        md = m & (kind == ch.MSG_W_DRAM) & st["dram_present"]
        d_idx = jnp.clip(addr, 0, DRAM_BACKING - 1)
        dram = dict(st["dram"])
        dram["data"] = dram["data"].at[jnp.where(md, d_idx, DRAM_BACKING)].set(data, mode="drop")
        dram["writes"] = dram["writes"] + md.sum().astype(jnp.int32)

        # --- CIM register writes (ordered) ---
        slot = addr >> 16
        reg = addr & 0xFFFF
        mc = m & (kind == ch.MSG_W_CIM)
        # CONFIG: last write wins per slot
        for u in range(cfg.n_cim_slots):
            mu = mc & (slot == u)
            mcfg = mu & (reg == isa.CIM_REG_CONFIG)
            any_cfg = mcfg.any()
            val = jnp.max(jnp.where(mcfg, data, -(2**31) + 1))
            cims = jax.tree.map(lambda x: x, cims)
            cims = _maybe_config(cims, u, any_cfg, val)
            # INPUT stream: ranked scatter preserving slot order
            mi = mu & (reg == isa.CIM_REG_INPUT)
            rank = jnp.cumsum(mi.astype(jnp.int32)) - 1
            pos = jnp.clip(cims["in_count"][u] + rank, 0, cim_mod.XBAR - 1)
            row = cims["in_buf"][u].at[jnp.where(mi, pos, cim_mod.XBAR)].set(data, mode="drop")
            cims = dict(cims)
            cims["in_buf"] = cims["in_buf"].at[u].set(row)
            cims["in_count"] = cims["in_count"].at[u].add(mi.sum().astype(jnp.int32))
            # weight loading
            mwr = mu & (reg == isa.CIM_REG_WROW)
            cims["wrow"] = cims["wrow"].at[u].set(
                jnp.where(mwr.any(), jnp.max(jnp.where(mwr, data, 0)), cims["wrow"][u])
            )
            # START: busy_until from the message's availability time
            mst = mu & (reg == isa.CIM_REG_START)
            t_start = jnp.maximum(t, jnp.max(jnp.where(mst, pending["t_avail"], 0)))
            cims = _maybe_start(cims, u, mst.any(), t_start)
            # MODE: switch dense VMM <-> spiking LIF (largest value wins within
            # one inbox round, same resolution rule as CIM_REG_CONFIG above)
            mmd = mu & (reg == isa.CIM_REG_MODE)
            cims = _maybe_mode(cims, u, mmd.any(), jnp.max(jnp.where(mmd, data, 0)))
            # COUNTS: arm a spike-count readback as of tick ``data`` (largest
            # target wins within one round); served at the quantum boundary
            if cfg.has_snn:
                mqr = mu & (reg == isa.CIM_REG_COUNTS)
                cims["count_req"] = cims["count_req"].at[u].set(
                    jnp.where(mqr.any(), jnp.max(jnp.where(mqr, data, 0)),
                              cims["count_req"][u])
                )

    # --- AER spikes: accumulate into each spike-mode unit's tick buffer ---
    spk_applied = jnp.zeros_like(m)
    if cfg.has_snn:
        spk = pending["valid"] & (kind == ch.MSG_SPIKE)
        slot_s = addr >> 16
        axon = addr & 0xFFFF
        # spikes a unit can never integrate — slot out of range, unit not in
        # spike mode, or never ticking (tick_period == 0) — are consumed and
        # dropped like real AER fabrics drop events addressed to
        # unconfigured cores; left pending they would wedge termination.
        # Out-of-range axons drop via the scatter, the event still consumes.
        # One fused scatter-add over a flattened (slot, axon) index handles
        # every slot at once (integer add is order-independent, so this is
        # bit-identical to the old per-slot loop and n_cim_slots× cheaper).
        in_range = spk & (slot_s >= 0) & (slot_s < cfg.n_cim_slots)
        su = jnp.clip(slot_s, 0, cfg.n_cim_slots - 1)
        # a unit that exhausted its tick horizon (tick_limit, cyclic nets)
        # can never integrate again: spikes emitted at its peers' final
        # tick would belong to tick tick_limit, which never fires — they
        # drop exactly like spikes to never-ticking units.  The ticks
        # counter only reaches the limit after the unit's last tick, and a
        # tick-k spike's t_avail exceeds next_tick until the receiver has
        # fired tick k itself, so eligibility is deterministic under every
        # segmentation and backend.
        eligible = in_range & (cims["tick_period"][su] > 0) & (
            cims["mode"][su] == isa.CIM_MODE_SPIKE
        ) & ((cims["tick_limit"][su] == 0)
             | (cims["ticks"][su] < cims["tick_limit"][su]))
        msu = eligible & (pending["t_avail"] <= cims["next_tick"][su])
        # only drop once the event has actually arrived in local time:
        # a future spike racing a runtime eligibility change must wait
        # for the reconfiguration to apply, not vanish early
        mdrop = in_range & ~eligible & (pending["t_avail"] <= t)
        # --- transport faults (faults.FaultConfig): seeded drop/duplication
        # decided at the consumption point.  The fate of a spike hashes pure
        # simulation coordinates — (seed, unit identity, axon, tick time) —
        # all of which are placement/backend/quantum-invariant, so a fixed
        # seed loses the identical spikes everywhere.  The event is still
        # consumed (spk_applied below keys on msu): a dropped spike vanishes
        # in flight, it does not linger in the channel. ---
        fc = cfg.faults
        integrated = msu
        data_eff = data
        if fc is not None and fc.has_transport_faults:
            from repro import faults as flt

            seed = st["faults"]["seed"]
            uid = cims["f_uid"][su]
            tick_t = cims["next_tick"][su]
            h = flt.hash_u32(seed, uid, axon, tick_t)
            th_drop = jnp.uint32(flt.threshold_u32(fc.p_spike_drop))
            dropped = msu & (h < th_drop)
            h2 = flt.hash_u32(seed, uid, axon, tick_t, 0xD0B1)
            th_dup = jnp.uint32(flt.threshold_u32(fc.p_spike_dup))
            duped = msu & ~dropped & (h2 < th_dup)
            integrated = msu & ~dropped
            data_eff = jnp.where(duped, data * 2, data)
        dead = cfg.n_cim_slots * cim_mod.XBAR
        tgt = jnp.where(integrated & (axon < cim_mod.XBAR),
                        su * cim_mod.XBAR + axon, dead)
        cims = dict(cims)
        cims["in_buf"] = cims["in_buf"].reshape(-1).at[tgt].add(
            jnp.where(integrated, data_eff, 0), mode="drop"
        ).reshape(cfg.n_cim_slots, cim_mod.XBAR)
        # consumed-spike accounting (obs/metrics.py): events integrated, per
        # unit and per segment — dropped/mis-addressed events don't count
        cims["spikes_in"] = cims["spikes_in"].at[
            jnp.where(integrated, su, cfg.n_cim_slots)
        ].add(1, mode="drop")
        spk_applied = (spk & ~in_range) | msu | mdrop

    st = dict(st)
    st["scratch"] = scratch
    st["dram"] = dram
    st["cims"] = cims
    st["stats"] = dict(st["stats"])
    retired = m | spk_applied
    consumed = retired.sum().astype(jnp.int32)
    st["stats"]["txn_hist"] = st["stats"]["txn_hist"].at[jnp.clip(kind, 0, 7)].add(
        retired.astype(jnp.int32)
    )
    if cfg.has_snn:
        st["stats"]["spikes_consumed"] = (
            st["stats"]["spikes_consumed"] + integrated.sum().astype(jnp.int32)
        )
        if cfg.faults is not None and cfg.faults.has_transport_faults:
            st["stats"]["spikes_dropped"] = (
                st["stats"]["spikes_dropped"] + dropped.sum().astype(jnp.int32)
            )
            st["stats"]["spikes_duped"] = (
                st["stats"]["spikes_duped"] + duped.sum().astype(jnp.int32)
            )

    if cfg.has_cpu:
        # --- blocking DRAM read requests: service now, respond via outbox ---
        responses = {"mask": m & (kind == ch.MSG_R_DRAM) & st["dram_present"],
                     "addr": d_idx, "tag": data,
                     "data": st["dram"]["data"][d_idx],
                     "t_req": pending["t_avail"]}

        # --- read responses: deliver to the waiting CPU (tag = rd register) ---
        mr = m & (kind == ch.MSG_R_RESP)
        has_resp = mr.any()
        resp_val = jnp.max(jnp.where(mr, data, 0))
        resp_rd = jnp.max(jnp.where(mr, addr, 0))
        cpu = st["cpu"]
        cpu = riscv.writeback(cpu, jnp.where(has_resp, resp_rd, 0), resp_val)
        cpu = dict(cpu)
        cpu["waiting"] = cpu["waiting"] & ~has_resp
        st["cpu"] = cpu
    else:
        responses = None  # no CPU ever issues MSG_R_DRAM; step skips service
        has_resp = jnp.array(False)

    pending = dict(pending)
    pending["valid"] = pending["valid"] & ~m & ~spk_applied
    return st, pending, responses, has_resp, consumed


def _maybe_config(cims, u, pred, val):
    new = cim_mod.apply_config(dict(cims), u, val, 0)
    return jax.tree.map(lambda a, b: jnp.where(pred, b, a), cims, new)


def _maybe_mode(cims, u, pred, val):
    new = cim_mod.apply_mode(dict(cims), u, val)
    return jax.tree.map(lambda a, b: jnp.where(pred, b, a), cims, new)


def _maybe_start(cims, u, pred, t_start):
    new = cim_mod.apply_start(dict(cims), u, t_start)
    return jax.tree.map(lambda a, b: jnp.where(pred, b, a), cims, new)


# ---------------------------------------------------------------------------
# instruction slots


def _mem_access(cfg: VPConfig, hot, dram_data, outbox, mem):
    """Dispatch one memory op; returns (hot, outbox, cycles, load_val, stall).

    HOT PATH — runs once per simulated instruction.  ``hot`` carries only
    small state (cpu, caches, scratch, DRAM scalars, store log); the 4 MB
    DRAM backing store is a read-only closure (``dram_data``), and local
    DRAM stores go to a write-log applied at the quantum boundary
    (posted-write TLM semantics; intra-quantum DRAM load-after-store is not
    forwarded — the benchmark programs never do it, O is write-only).
    Keeping big arrays out of the slot-scan carry is what makes the
    simulator fast: XLA double-buffers carried arrays it cannot alias
    (2 × 4 MB per instruction in the naive formulation).
    """
    t = cfg.timing
    addr = mem["addr"]
    widx = (addr >> 2) & (DRAM_BACKING - 1)
    is_scratch = (addr >= isa.SCRATCH_BASE) & (addr < isa.SCRATCH_BASE + SCRATCH_WORDS * 4)
    is_cim = (addr >= isa.CIM_BASE) & (addr < isa.SCRATCH_BASE)
    is_dram = (addr >= 0) & (addr < isa.CIM_BASE)
    s_idx = jnp.clip((addr - isa.SCRATCH_BASE) >> 2, 0, SCRATCH_WORDS - 1)

    hot = dict(hot)
    ld = mem["is_load"]
    sd = mem["is_store"]
    use_dram_r = ld & is_dram & hot["dram_present"]
    local_dram_w = sd & is_dram & hot["dram_present"]
    touch_dram = use_dram_r | local_dram_w

    hot["dcache"], hit = memory.cache_lookup(hot["dcache"], widx, t, touch_dram)
    hot["dram_meta"], dcost = memory.dram_cost(
        hot["dram_meta"], widx, local_dram_w, t, touch_dram & ~hit
    )

    val = jnp.where(is_scratch, hot["scratch"][s_idx], dram_data[widx])
    cycles = jnp.where(
        ld,
        jnp.where(is_scratch, t.scratch,
                  jnp.where(use_dram_r, jnp.where(hit, t.cache_hit, dcost), t.cpi)),
        0,
    )

    # remote DRAM load -> blocking request (tag = seg_id << 8 | rd)
    remote_ld = ld & is_dram & ~hot["dram_present"]
    outbox = ch.box_append(
        outbox, remote_ld, ch.MSG_R_DRAM, cfg.dram_segment, widx,
        (hot["seg_id"] << 8) | mem["rd"], hot["time"],
    )

    # stores (targeted scatters; masked ops write a dead slot)
    local_sc = sd & is_scratch
    hot["scratch"] = hot["scratch"].at[
        jnp.where(local_sc, s_idx, SCRATCH_WORDS)
    ].set(mem["st_data"], mode="drop")
    log = dict(hot["store_log"])
    li = jnp.where(local_dram_w, jnp.clip(log["count"], 0, cfg.store_log - 1), cfg.store_log)
    log["addr"] = log["addr"].at[li].set(widx, mode="drop")
    log["data"] = log["data"].at[li].set(mem["st_data"], mode="drop")
    log["count"] = log["count"] + local_dram_w.astype(jnp.int32)
    hot["store_log"] = log
    cycles = cycles + jnp.where(
        sd,
        jnp.where(is_scratch, t.scratch,
                  jnp.where(local_dram_w, jnp.where(hit, t.cache_hit, dcost), t.mmio_post)),
        0,
    )
    # remote/posted stores: DRAM (remote) or CIM MMIO
    remote_st_dram = sd & is_dram & ~hot["dram_present"]
    outbox = ch.box_append(
        outbox, remote_st_dram, ch.MSG_W_DRAM, cfg.dram_segment, widx,
        mem["st_data"], hot["time"],
    )
    if len(cfg.cim_seg):
        u_global = jnp.clip((addr - isa.CIM_BASE) >> 12, 0, max(len(cfg.cim_seg) - 1, 0))
        reg_off = addr & 0xFFF
        seg_arr = jnp.asarray(cfg.cim_seg, jnp.int32)
        slot_arr = jnp.asarray(cfg.cim_slot, jnp.int32)
        cim_store = sd & is_cim
        is_spk = cim_store & (reg_off == isa.CIM_REG_SPIKE)
        if cfg.snn_tick_period > 0:
            # tick-addressed AER injection: the store names a LIF tick, not a
            # register value, and becomes a MSG_SPIKE whose t_avail is pinned
            # to the tick's grid time — t_emit backs the routing latency out,
            # so under ANY placement the event arrives tagged exactly like a
            # pre-scheduled raster event of the same timestep (bit-identical
            # tick bucketing; snn/topology.py _inject_raster).
            tick = (mem["st_data"] >> 16) & 0x7FFF
            target_t = (tick + 1) * cfg.snn_tick_period
            lat = jnp.where(seg_arr[u_global] == hot["seg_id"],
                            cfg.local_latency, cfg.channel_latency)
            outbox = ch.box_append(
                outbox, is_spk, ch.MSG_SPIKE, seg_arr[u_global],
                (slot_arr[u_global] << 16) | (mem["st_data"] & 0xFFFF),
                jnp.ones((), jnp.int32), target_t - lat,
            )
            # deadline contract (docs/architecture.md, "CPU spike injection"):
            # a tick-k spike must be issued at CPU local time < (k+1)*period —
            # later stores may or may not beat the receiver's gate, so they
            # are flagged sticky-loud instead of resolving timing-dependently
            late = is_spk & (hot["time"] >= target_t)
        else:
            late = is_spk  # no ticking spike-mode unit wired: never valid
        hot["stats"] = dict(hot["stats"])
        hot["stats"]["snn_mmio_late"] = (
            hot["stats"]["snn_mmio_late"] + late.astype(jnp.int32)
        )
        outbox = ch.box_append(
            outbox, cim_store & ~is_spk, ch.MSG_W_CIM, seg_arr[u_global],
            (slot_arr[u_global] << 16) | reg_off, mem["st_data"], hot["time"],
        )
    return hot, outbox, cycles, val, remote_ld


def make_segment_step(cfg: VPConfig, quantum: int, obs=None):
    """Compile-ready pure step for ONE segment.

    ``obs`` (an ``obs.trace.TraceConfig`` or None) is *static*: when None —
    the default — every telemetry emission below is dead code and the
    compiled step is byte-for-byte the untraced hot path.  When set, the
    emission sites collect masked *lanes* (pure bookkeeping on values the
    step already computes) and the step appends them all to the
    per-segment ring riding in ``st["trace"]`` (attached by the
    controller) with ONE ``emit_bulk`` at the end — a single handful-of-
    lanes scatter per round, which is what keeps the telemetry overhead
    small in the dispatch-bound megaloop regime.  Emissions never read the
    ring contents, only append, so they cannot perturb simulation state —
    traced runs are bit-identical to untraced runs minus the ring itself.
    """
    t = cfg.timing
    if obs is not None:
        from repro.obs import trace as tr

    def step(st, pending, t_limit):
        t_inbox = st["time"]  # the SNN tick gate: time the inbox was applied at
        if obs is not None:
            lanes = []  # (mask, kind, unit, t, value) rows, emitted in order

            def lane(mask, kind, unit, tt, value):
                mask = jnp.atleast_1d(jnp.asarray(mask))
                n = mask.shape[0]
                b = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.int32), (n,))
                lanes.append((mask, b(kind), b(unit), b(tt), b(value)))

            occ0 = pending["valid"].sum().astype(jnp.int32)
            instr0 = st["stats"]["instrs"]
            cim_state0 = st["cims"]["state"]
            transport_on = (cfg.faults is not None
                            and cfg.faults.has_transport_faults)
            if transport_on:
                drop0 = st["stats"]["spikes_dropped"]
                dup0 = st["stats"]["spikes_duped"]
        st, pending, responses, _, consumed = _apply_inbox(cfg, st, pending)
        if obs is not None:
            lane(consumed > 0, tr.EV_ROUTE, occ0, t_inbox, consumed)
            if transport_on:
                # one fault_injected event per round that injected: unit
                # carries the duplication count, value the drop count
                d_drop = st["stats"]["spikes_dropped"] - drop0
                d_dup = st["stats"]["spikes_duped"] - dup0
                lane((d_drop + d_dup) > 0, tr.EV_FAULT, d_dup, t_inbox,
                     d_drop)
            if cfg.has_cpu:
                # a dense OP can only launch via an MMIO START in this inbox
                started = ((st["cims"]["state"] == isa.CIM_ST_OP)
                           & (cim_state0 != isa.CIM_ST_OP))
                lane(started, tr.EV_CIM_START, jnp.arange(cfg.n_cim_slots),
                     t_inbox, st["cims"]["busy_until"])
        outbox = ch.empty_box(cfg.out_cap)

        if cfg.has_cpu:
            # service queued DRAM read requests -> responses
            r = responses
            outbox = ch.box_append_bulk(
                outbox, r["mask"], ch.MSG_R_RESP,
                r["tag"] >> 8,          # requester segment travels in the tag
                r["tag"] & 0xFF,        # rd register index
                r["data"],
                jnp.maximum(st["time"], r["t_req"]) + t.dram_access,
            )

        dram_data = st["dram"]["data"]
        prog = st["prog"]
        hot = None if not cfg.has_cpu else {
            "time": st["time"],
            "seg_id": st["seg_id"],
            "dram_present": st["dram_present"],
            "cpu": st["cpu"],
            "icache": st["icache"],
            "dcache": st["dcache"],
            "dram_meta": {k: v for k, v in st["dram"].items() if k != "data"},
            "scratch": st["scratch"],
            "stats": st["stats"],
            "store_log": {
                "addr": jnp.zeros((cfg.store_log,), jnp.int32),
                "data": jnp.zeros((cfg.store_log,), jnp.int32),
                "count": jnp.zeros((), jnp.int32),
            },
        }

        def slot(carry, _):
            hot, outbox = carry
            cpu = hot["cpu"]
            runnable = (
                cpu["present"] & ~cpu["halted"] & ~cpu["waiting"] & (hot["time"] < t_limit)
            )
            pc_w = (cpu["pc"] >> 2) & (PROG_WORDS - 1)
            instr = prog[pc_w]
            hot = dict(hot)
            hot["icache"], ihit = memory.cache_lookup(hot["icache"], pc_w, t, runnable)
            cpu2, mem = riscv.execute(cpu, instr)
            mem = {k: (v & runnable if v.dtype == jnp.bool_ else v) for k, v in mem.items()}
            hot, outbox, mcycles, ld_val, stall = _mem_access(cfg, hot, dram_data, outbox, mem)
            # cpu state is tiny (35 words): whole-select is fine here
            cpu2 = jax.tree.map(lambda a, b: jnp.where(runnable, b, a), cpu, cpu2)
            did_load_local = mem["is_load"] & ~stall
            wb_rd = jnp.where(did_load_local, mem["rd"], 0)
            cpu2 = riscv.writeback(cpu2, wb_rd, jnp.where(did_load_local, ld_val, cpu2["regs"][0]))
            cpu2 = dict(cpu2)
            cpu2["waiting"] = cpu["waiting"] | stall
            cost = jnp.where(runnable, t.cpi + mcycles + jnp.where(ihit, 0, t.imiss), 1)
            new_time = jnp.minimum(hot["time"] + cost, t_limit)
            hot["time"] = jnp.where(cpu["present"] & ~cpu["halted"], new_time, hot["time"])
            hot["cpu"] = cpu2
            hot["stats"] = dict(hot["stats"])
            hot["stats"]["instrs"] = hot["stats"]["instrs"] + runnable.astype(jnp.int32)
            return (hot, outbox), None

        if cfg.has_cpu:
            (hot, outbox), _ = jax.lax.scan(slot, (hot, outbox), None, length=quantum)

            # apply the DRAM store log in order (sequential: duplicate-safe)
            def apply_store(data, i):
                valid = i < hot["store_log"]["count"]
                a = jnp.where(valid, hot["store_log"]["addr"][i], DRAM_BACKING - 1)
                return data.at[a].set(jnp.where(valid, hot["store_log"]["data"][i], data[a])), None

            dram_data, _ = jax.lax.scan(apply_store, dram_data, jnp.arange(cfg.store_log))

            st = dict(st)
            st["time"] = hot["time"]
            st["cpu"] = hot["cpu"]
            st["icache"] = hot["icache"]
            st["dcache"] = hot["dcache"]
            st["scratch"] = hot["scratch"]
            st["stats"] = dict(hot["stats"])
            # sticky watermark: past-capacity store-log appends clip onto the
            # last slot (silently lost stores), so a quantum that needed more
            # than cfg.store_log entries must raise loudly in the controller
            st["stats"]["store_peak"] = jnp.maximum(
                st["stats"]["store_peak"], hot["store_log"]["count"]
            )
            st["dram"] = {**hot["dram_meta"], "data": dram_data}
        else:
            st = dict(st)  # CPU-free: the instruction machinery is dead code

        # passive segments (no CPU or halted) advance to the decoupling bound
        passive = ~st["cpu"]["present"] | st["cpu"]["halted"]
        st["time"] = jnp.where(passive, jnp.maximum(st["time"], t_limit), st["time"])

        # --- CIM completion at the quantum boundary ---
        # statically dead on a CPU-free platform: a dense OP only enters
        # state 2 via an MMIO START, which only a CPU can issue (the builder
        # keeps has_cpu True if cim_init presets an in-flight OP)
        if cfg.has_cpu:
            cims, done = cim_mod.finish_ops(st["cims"], st["time"], cfg.use_kernel)
            st["cims"] = cims
            if obs is not None:
                lane(done, tr.EV_CIM_DONE, jnp.arange(cfg.n_cim_slots),
                     jnp.maximum(cims["op_done_at"], 0), cims["rows"])
            for u in range(cfg.n_cim_slots):
                du = done[u]
                rows = jnp.arange(cim_mod.XBAR)
                mask_rows = du & (rows < cims["rows"][u])
                outbox = ch.box_append_bulk(
                    outbox, mask_rows, ch.MSG_W_SCRATCH, cims["mgr_seg"][u],
                    cims["out_addr"][u] + rows, cims["out_buf"][u],
                    jnp.maximum(cims["busy_until"][u], 0),
                )
                outbox = ch.box_append(
                    outbox, du, ch.MSG_W_SCRATCH, cims["mgr_seg"][u],
                    cims["flag_addr"][u], jnp.ones((), jnp.int32), cims["busy_until"][u],
                )

        # --- SNN tick at the quantum boundary: LIF integration + AER out ---
        if cfg.has_snn:
            cims, fired_rows, fire, tick_time = cim_mod.snn_tick(
                st["cims"], t_inbox, cfg.use_kernel, cfg.snn_grouped
            )
            st["cims"] = cims
            if obs is not None:
                lane(fire, tr.EV_TICK, jnp.arange(cfg.n_cim_slots),
                     tick_time, fired_rows.sum(-1).astype(jnp.int32))
            rows = jnp.arange(cim_mod.XBAR)
            for u in range(cfg.n_cim_slots):
                for d in range(cfg.snn_fanout):
                    # fan-out entry d routes neuron rows [row_lo, row_hi) to
                    # (dst_seg, dst_slot) at axon axon_base + row; axons past
                    # the 16-bit AER field would carry into the slot bits and
                    # misroute; drop them at the source instead
                    dst_axon = cims["axon_base"][u, d] + rows
                    emit = (
                        fired_rows[u]
                        & (cims["dst_seg"][u, d] >= 0)
                        & (rows >= cims["row_lo"][u, d])
                        & (rows < cims["row_hi"][u, d])
                        & (dst_axon >= 0) & (dst_axon < (1 << 16))
                    )
                    outbox = ch.box_append_bulk(
                        outbox, emit, ch.MSG_SPIKE, cims["dst_seg"][u, d],
                        (cims["dst_slot"][u, d] << 16) | dst_axon,
                        jnp.ones((), jnp.int32), tick_time[u],
                    )
                    if obs is not None:
                        # one EV_SPIKE_TX per (unit, fan-out entry) tick
                        # burst; value packs destination + spike count so
                        # export.py can draw cross-segment flow arrows
                        n_spk = emit.sum().astype(jnp.int32)
                        lane(fire[u] & (cims["dst_seg"][u, d] >= 0)
                             & (n_spk > 0),
                             tr.EV_SPIKE_TX, u, tick_time[u],
                             (cims["dst_seg"][u, d] << 16) | n_spk)

        # --- spike-count readback service (CIM_REG_COUNTS, hybrid jobs) ---
        # a pending request is served at the first boundary where the unit's
        # tick counter has reached the target (ticks increment by one per
        # boundary, so the first crossing is exact) or the unit can never
        # tick again (horizon exhausted / reconfigured) — either way the
        # DMA'd counts are a pure function of the tick grid, never of round
        # timing.  Delivery mirrors dense completion: spike_counts rows to
        # the manager's OUT area, then 1 to the flag word.
        if cfg.has_cpu and cfg.has_snn:
            cims = st["cims"]
            can_tick = (
                (cims["mode"] == isa.CIM_MODE_SPIKE) & (cims["tick_period"] > 0)
                & ((cims["tick_limit"] == 0) | (cims["ticks"] < cims["tick_limit"]))
            )
            serve = (
                cims["present"] & (cims["count_req"] >= 0)
                & ((cims["ticks"] >= cims["count_req"]) | ~can_tick)
            )
            rows = jnp.arange(cim_mod.XBAR)
            for u in range(cfg.n_cim_slots):
                mask_rows = serve[u] & (rows < cims["rows"][u])
                outbox = ch.box_append_bulk(
                    outbox, mask_rows, ch.MSG_W_SCRATCH, cims["mgr_seg"][u],
                    cims["out_addr"][u] + rows, cims["spike_counts"][u],
                    st["time"],
                )
                outbox = ch.box_append(
                    outbox, serve[u], ch.MSG_W_SCRATCH, cims["mgr_seg"][u],
                    cims["flag_addr"][u], jnp.ones((), jnp.int32), st["time"],
                )
            # a request served past its target tick is timing-dependent (the
            # CPU asked too late): flag it sticky-loud like late injections
            late_read = serve & (cims["ticks"] > cims["count_req"])
            st["cims"] = dict(cims)
            st["cims"]["count_req"] = jnp.where(serve, -1, cims["count_req"])
            st["stats"] = dict(st["stats"])
            st["stats"]["snn_mmio_late"] = (
                st["stats"]["snn_mmio_late"] + late_read.sum().astype(jnp.int32)
            )
        st["stats"] = dict(st["stats"])
        st["stats"]["msgs"] = st["stats"]["msgs"] + outbox["count"]
        # sticky watermark: past-capacity appends are silently lost (bulk
        # and single appends both drop past-cap writes), so a peak beyond
        # out_cap means emitted messages (e.g. a wide SNN tick's AER burst)
        # were dropped — checked loudly by the controller alongside the
        # inbox watermark (or counted as loss under the drop policy below)
        st["stats"]["outbox_peak"] = jnp.maximum(st["stats"]["outbox_peak"], outbox["count"])
        if cfg.faults is not None and cfg.faults.drop_overflow:
            # graceful degradation: the appends above already truncated
            # past-capacity messages, so the demand beyond out_cap this
            # round IS the loss — count it instead of letting the watermark
            # abort (controller skips the outbox raise under this policy)
            lost_now = jnp.maximum(outbox["count"] - cfg.out_cap, 0)
            st["stats"]["outbox_lost"] = st["stats"]["outbox_lost"] + lost_now
        if obs is not None:
            dt = st["time"] - t_inbox
            lane(dt > 0, tr.EV_QUANTUM, st["stats"]["instrs"] - instr0,
                 t_inbox, dt)
            if cfg.faults is not None and cfg.faults.drop_overflow:
                # spikes_dropped lane: messages lost to outbox truncation
                # this round (inbox-side losses accumulate in
                # pending["lost_total"], outside the per-segment ring)
                lane(lost_now > 0, tr.EV_SPIKE_LOSS, -1, st["time"],
                     lost_now)
            # watermark trips, deduped through the ring's wmark_seen bitmask
            # so each flag traces once per segment (the flag itself stays
            # sticky in stats/pending; detection here is advisory telemetry,
            # the controller still raises from termination_flags)
            trip = (
                (pending["max_count"] > cfg.in_cap).astype(jnp.int32)
                | ((st["stats"]["outbox_peak"] > cfg.out_cap).astype(jnp.int32) << 1)
                | ((st["stats"]["store_peak"] > cfg.store_log).astype(jnp.int32) << 2)
                | ((st["stats"]["snn_mmio_late"] > 0).astype(jnp.int32) << 3)
            )
            new = trip & ~st["trace"]["wmark_seen"]
            wbit = jnp.arange(len(tr.WMARK_NAMES))
            lane(((new >> wbit) & 1).astype(bool), tr.EV_WMARK,
                 jnp.full(wbit.shape, -1), st["time"], wbit)
            # the one ring append of the whole step: every site above only
            # collected lanes
            mask, kind, unit, tt, value = (jnp.concatenate(xs)
                                           for xs in zip(*lanes))
            ring = dict(tr.emit_bulk(st["trace"], mask, kind, st["seg_id"],
                                     unit, tt, value))
            ring["wmark_seen"] = ring["wmark_seen"] | trip
            st["trace"] = ring
        return st, outbox, pending

    return step


# ---------------------------------------------------------------------------
# termination / overflow reducer


def termination_flags(states, pending, in_cap: int, out_cap: int,
                      store_log: int):
    """Traced ``(done, inbox_over, outbox_over, store_over, mmio_late,
    trace_over)`` over the stacked simulation.

    This is the controller's termination predicate and overflow watermark
    check as *traced* code, so it runs both host-side (one fused device
    sync instead of separate ``bool(jnp.any(...))`` round-trips) and
    inside the device-resident megaloop's ``lax.while_loop`` (no host
    round-trip at all).  Semantics mirror the original host-side checks:

    - ``done``: no present-and-running CPU, no CIM unit with an in-flight
      OP (merely armed units are not forward progress), no spike-mode unit
      that will still change observable state at its next tick
      (accumulated-but-unintegrated spikes, or an active neuron already at
      threshold — possible when a runtime CIM_REG_MODE write lowers thresh
      under a charged membrane; units that never tick can never drain and
      are not busy, and units that exhausted their ``tick_limit`` horizon —
      recurrent nets can self-sustain forever — are done by definition),
      no unit with a pending spike-count readback (``count_req`` — the
      unit must keep ticking to the requested count and answer before the
      run may end), and no valid pending message.  With an empty buffer
      and everyone subthreshold, leak alone can never cross threshold
      (leak >= 0, reset-to-zero), so idling is final.
    - ``inbox_over`` / ``outbox_over`` / ``store_over``: the sticky
      high-water marks carried in the state ever exceeded in_cap /
      out_cap / store_log (see ``channel.inbox_overflowed``); the
      controller raises host-side with the cap kwarg to fix.
    - ``mmio_late``: the sticky ``snn_mmio_late`` counter is nonzero — a
      hybrid MMIO op (CIM_REG_SPIKE / CIM_REG_COUNTS) violated its
      tick-grid deadline, so its effect would be round-timing-dependent;
      the controller raises instead of returning placement-dependent
      results.
    - ``trace_over`` (flag 6): the telemetry ring's sticky overflow mark
      (obs/trace.py) — events were dropped to ring capacity.  Unlike every
      other watermark this one is *informational only*: telemetry loss
      must never stop or perturb a simulation, so the controller reports
      it (``Controller.trace_lost``) instead of raising, and it is
      excluded from the megaloop's early-exit predicate.  Constant False
      when tracing is disabled (no ring in the state).
    """
    from repro.vp import isa

    cpus = states["cpu"]
    active_cpu = jnp.any(cpus["present"] & ~cpus["halted"])
    cims = states["cims"]
    busy_cim = jnp.any(cims["state"] == 2)
    ticking = (
        (cims["mode"] == isa.CIM_MODE_SPIKE) & (cims["tick_period"] > 0)
        & ((cims["tick_limit"] == 0) | (cims["ticks"] < cims["tick_limit"]))
    )
    pending_in = (cims["in_buf"] != 0).any(-1)
    # neuron faults shift the firing predicate, and the termination check
    # must shift with it: a dead neuron is never due, a drifted threshold
    # is due at its *effective* threshold — otherwise a faulted network
    # would wedge (or quit early) at the quiesce check
    thr = cims["thresh"][..., None]
    if "f_dth" in cims:
        thr = jnp.maximum(thr + cims["f_dth"], 1)
    due = (cims["v"] >= thr) & (cims["refrac"] == 0)
    if "f_dead" in cims:
        due = due & ~cims["f_dead"]
    due = due.any(-1)
    busy_snn = jnp.any(ticking & (pending_in | due))
    busy_req = jnp.any(cims["present"] & (cims["count_req"] >= 0))
    msgs = jnp.any(pending["valid"])
    done = ~(active_cpu | busy_cim | busy_snn | busy_req | msgs)
    inbox_over = ch.inbox_overflowed(pending, in_cap)
    outbox_over = (states["stats"]["outbox_peak"] > out_cap).any()
    store_over = (states["stats"]["store_peak"] > store_log).any()
    mmio_late = (states["stats"]["snn_mmio_late"] > 0).any()
    trace_over = (states["trace"]["overflowed"].any() if "trace" in states
                  else jnp.array(False))
    return done, inbox_over, outbox_over, store_over, mmio_late, trace_over


def job_termination_flags(states, pending, in_cap, out_cap, store_log):
    """Per-job ``termination_flags`` over a leading *job* axis.

    ``states``/``pending`` are ``(J, S, ...)`` stacks of J independent
    platforms (the serving job axis — core/controller.py's
    ``_job_megaloop``); the caps are ``(J,)`` int32 arrays, so every job is
    judged against its *own* capacities.  Everything in
    ``termination_flags`` is traced comparisons against the caps — nothing
    shapes on them — which is what makes cap-padded serving buckets legal:
    the physical boxes are sized to the bucket maximum, but a job whose
    demand exceeds its own (smaller) cap still trips its watermark at
    exactly the check round its solo run would, with the identical
    true-demand watermark value in the host-side error.  Returns six
    ``(J,)`` bool arrays in ``termination_flags`` order.
    """
    return jax.vmap(termination_flags)(states, pending, in_cap, out_cap,
                                       store_log)
