"""Two-pass assembler for the RV32IM subset + the VMM benchmark programs.

Syntax: one instruction per line, ``label:`` definitions, ``%lo(sym)`` not
needed (flat immediates), registers by ABI name.  Supported mnemonics:

  lui rd, imm20        auipc rd, imm20
  jal rd, label        jalr rd, rs1, imm
  beq/bne/blt/bge rs1, rs2, label
  lw rd, imm(rs1)      sw rs2, imm(rs1)
  addi rd, rs1, imm    add/sub/mul rd, rs1, rs2
  li rd, imm           (pseudo: lui+addi or addi)
  nop / halt           (halt = jal x0, 0 — self-loop, detected by the ISS)
"""
from __future__ import annotations

import re

import numpy as np

from repro.vp import isa


def assemble(src: str, base: int = 0) -> np.ndarray:
    lines = []
    for raw in src.splitlines():
        line = raw.split("#")[0].strip()
        if line:
            lines.append(line)

    # pass 1: labels
    labels: dict[str, int] = {}
    pc = base
    prog: list[str] = []
    for line in lines:
        while True:
            m = re.match(r"^([\w.]+):\s*(.*)$", line)
            if not m:
                break
            labels[m.group(1)] = pc
            line = m.group(2).strip()
        if not line:
            continue
        op = line.split()[0]
        if op == "li":
            _, rd, imm = _split(line)
            pc += 4 if _fits12(int(imm, 0)) else 8
        else:
            pc += 4
        prog.append(line)

    # pass 2: encode
    words: list[int] = []
    pc = base
    for line in prog:
        parts = _split(line)
        op = parts[0]
        if op == "li":
            rd, imm = isa.reg(parts[1]), int(parts[2], 0)
            if _fits12(imm):
                words.append(isa.enc_i(isa.OP_IMM, rd, isa.F3_ADDI, 0, imm))
                pc += 4
            else:
                hi = (imm + 0x800) & 0xFFFFF000
                lo = imm - hi
                words.append(isa.enc_u(isa.OP_LUI, rd, hi))
                words.append(isa.enc_i(isa.OP_IMM, rd, isa.F3_ADDI, rd, lo))
                pc += 8
            continue
        if op == "nop":
            words.append(isa.enc_i(isa.OP_IMM, 0, isa.F3_ADDI, 0, 0))
        elif op == "halt":
            words.append(isa.enc_j(isa.OP_JAL, 0, 0))
        elif op == "lui":
            words.append(isa.enc_u(isa.OP_LUI, isa.reg(parts[1]), int(parts[2], 0)))
        elif op == "jal":
            rd = isa.reg(parts[1])
            words.append(isa.enc_j(isa.OP_JAL, rd, labels[parts[2]] - pc))
        elif op == "jalr":
            words.append(
                isa.enc_i(isa.OP_JALR, isa.reg(parts[1]), 0, isa.reg(parts[2]), int(parts[3], 0))
            )
        elif op in ("beq", "bne", "blt", "bge"):
            f3 = {"beq": isa.F3_BEQ, "bne": isa.F3_BNE, "blt": isa.F3_BLT, "bge": isa.F3_BGE}[op]
            words.append(
                isa.enc_b(isa.OP_BRANCH, f3, isa.reg(parts[1]), isa.reg(parts[2]), labels[parts[3]] - pc)
            )
        elif op == "lw":
            rd, (imm, rs1) = isa.reg(parts[1]), _memarg(parts[2])
            words.append(isa.enc_i(isa.OP_LOAD, rd, isa.F3_LW, rs1, imm))
        elif op == "sw":
            rs2, (imm, rs1) = isa.reg(parts[1]), _memarg(parts[2])
            words.append(isa.enc_s(isa.OP_STORE, isa.F3_SW, rs1, rs2, imm))
        elif op == "addi":
            words.append(
                isa.enc_i(isa.OP_IMM, isa.reg(parts[1]), isa.F3_ADDI, isa.reg(parts[2]), int(parts[3], 0))
            )
        elif op in ("add", "sub", "mul"):
            f7 = {"add": 0, "sub": 0b0100000, "mul": isa.F7_MULDIV}[op]
            words.append(
                isa.enc_r(isa.OP_REG, isa.reg(parts[1]), isa.F3_ADD, isa.reg(parts[2]), isa.reg(parts[3]), f7)
            )
        else:
            raise ValueError(f"unknown mnemonic: {line}")
        pc += 4
    return np.array(words, dtype=np.uint32)


def _split(line: str):
    op, _, rest = line.partition(" ")
    parts = [op] + [p.strip() for p in rest.split(",") if p.strip()]
    return parts


def _fits12(v: int) -> bool:
    return -2048 <= v < 2048


def _memarg(s: str):
    m = re.match(r"(-?\w+)\((\w+)\)$", s)
    return int(m.group(1), 0), isa.reg(m.group(2))


# ---------------------------------------------------------------------------
# benchmark programs


def vmm_riscv_program(h: int, w: int, p: int, a_base: int, b_base: int, o_base: int) -> str:
    """The paper's nested-loop VMM on RISC-V + main memory: O[h,p] = A[h,w] @ B[w,p].

    Word-addressed int32 matrices, row-major.
    """
    return f"""
    li s0, 0                 # i = 0
outer_i:
    li s1, 0                 # j = 0
outer_j:
    li t0, 0                 # acc = 0
    li s2, 0                 # k = 0
    li t4, {w * 4}
    mul t2, s0, t4           # i*w*4
    li t4, {a_base}
    add t2, t2, t4           # t2 = &A[i,0]
    add t3, s1, s1
    add t3, t3, t3           # j*4
    li t4, {b_base}
    add t3, t3, t4           # t3 = &B[0,j]
inner_k:
    lw t4, 0(t2)             # A[i,k]
    lw t5, 0(t3)             # B[k,j]
    mul t6, t4, t5
    add t0, t0, t6
    addi t2, t2, 4
    addi t3, t3, {4 * p}
    addi s2, s2, 1
    li t4, {w}
    blt s2, t4, inner_k
    # O[i,j] = acc
    li t4, {p * 4}
    mul t1, s0, t4
    add t5, s1, s1
    add t5, t5, t5
    add t1, t1, t5           # i*p*4 + j*4
    li t4, {o_base}
    add t1, t1, t4
    sw t0, 0(t1)
    addi s1, s1, 1
    li t4, {p}
    blt s1, t4, outer_j
    addi s0, s0, 1
    li t4, {h}
    blt s0, t4, outer_i
    halt
"""


def vmm_cim_program(h: int, w: int, p: int, cim_base: int, b_base: int, o_base: int,
                    in_res: int = 8, out_res: int = 8) -> str:
    """Offloaded VMM: configure the CIM unit, stream each input vector,
    launch OP, poll STATUS, read back outputs.  (Weights A are preloaded into
    the crossbar by the platform, as in the paper — the crossbar holds the
    matrix; the IN/OP/OUT phases run per vector.)
    """
    cfg = (h & 0x1FF) | (w & 0x1FF) << 9 | (in_res & 0xF) << 18 | (out_res & 0xF) << 22
    return f"""
    li s0, {cim_base}
    li t0, {cfg}
    sw t0, {isa.CIM_REG_CONFIG}(s0)
    li s1, 0                 # j = 0 (vector index)
vec_loop:
    # stream w input elements B[k, j]
    li s2, 0
    li t3, {b_base}
    add t3, t3, s1
    add t3, t3, s1
    add t3, t3, s1
    add t3, t3, s1           # &B[0,j]
in_loop:
    lw t4, 0(t3)
    sw t4, {isa.CIM_REG_INPUT}(s0)
    addi t3, t3, {4 * p}
    addi s2, s2, 1
    li t5, {w}
    blt s2, t5, in_loop
    sw zero, {isa.CIM_REG_START}(s0)
poll:
    lw t4, {isa.CIM_REG_STATUS}(s0)
    li t5, {isa.CIM_ST_OUT}
    bne t4, t5, poll
    # read h outputs -> O[:, j]
    li s2, 0
    li t3, {o_base}
    add t3, t3, s1
    add t3, t3, s1
    add t3, t3, s1
    add t3, t3, s1
out_loop:
    lw t4, {isa.CIM_REG_OUTPUT}(s0)
    sw t4, 0(t3)
    addi t3, t3, {4 * p}
    addi s2, s2, 1
    li t5, {h}
    blt s2, t5, out_loop
    addi s1, s1, 1
    li t5, {p}
    blt s1, t5, vec_loop
    halt
"""
