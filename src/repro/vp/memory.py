"""Memory modules: shared DRAM with page-switch / write→read-switch delays
(after [26] in the paper) and direct-mapped L1 caches.

All state is jnp arrays so segment steps stay vmap/shard_map-able.  The
modeled DRAM capacity (128 MB, Table II) is a VP parameter; the backing
store is sized to the benchmark working set (1 MiB of words).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.vp import isa


@dataclasses.dataclass(frozen=True)
class Timing:
    """Cycle costs (CPU @1.7 GHz domain, Table II)."""

    cpi: int = 1
    scratch: int = 1
    cache_hit: int = 1
    dram_access: int = 20
    page_switch: int = 8
    write_read_switch: int = 3
    imiss: int = 10
    mmio_post: int = 1
    dram_row_bits: int = 9  # words per row = 512
    dcache_sets: int = 1024  # 32 KB / 32 B lines
    icache_sets: int = 512  # 16 KB
    line_words: int = 8


def cache_state(n_sets: int):
    return {
        "tags": jnp.full((n_sets,), -1, jnp.int32),
        "hits": jnp.zeros((), jnp.int32),
        "misses": jnp.zeros((), jnp.int32),
    }


def dram_state(backing_words: int = isa.DRAM_WORDS):
    return {
        "data": jnp.zeros((backing_words,), jnp.int32),
        "last_row": jnp.full((), -1, jnp.int32),
        "last_write": jnp.zeros((), jnp.bool_),
        "reads": jnp.zeros((), jnp.int32),
        "writes": jnp.zeros((), jnp.int32),
    }


def cache_lookup(cache, word_addr, t: Timing, pred):
    """Returns (cache', hit). All mutations are gated on ``pred`` via
    targeted scatters — never a whole-array select (hot path: runs once per
    simulated instruction)."""
    line = word_addr // t.line_words
    n = cache["tags"].shape[0]
    s = line % n
    hit = cache["tags"][s] == line
    cache = dict(cache)
    cache["tags"] = cache["tags"].at[s].set(jnp.where(pred, line, cache["tags"][s]))
    cache["hits"] = cache["hits"] + (pred & hit).astype(jnp.int32)
    cache["misses"] = cache["misses"] + (pred & ~hit).astype(jnp.int32)
    return cache, hit


def dram_cost(dram, word_addr, is_write, t: Timing, pred):
    """Returns (dram', cycles) applying row-buffer + wr->rd switch penalties.
    Scalar state only — gated on ``pred``; never touches the data array."""
    row = word_addr >> t.dram_row_bits
    cost = t.dram_access
    cost = cost + jnp.where(row != dram["last_row"], t.page_switch, 0)
    cost = cost + jnp.where(dram["last_write"] & ~is_write, t.write_read_switch, 0)
    dram = dict(dram)
    dram["last_row"] = jnp.where(pred, row, dram["last_row"])
    dram["last_write"] = jnp.where(pred, is_write, dram["last_write"])
    dram["reads"] = dram["reads"] + (pred & ~is_write).astype(jnp.int32)
    dram["writes"] = dram["writes"] + (pred & is_write).astype(jnp.int32)
    return dram, cost
