"""RISC-V RV32IM-subset encodings + the CIM micro-instruction register map.

The VP integrates a SystemC RV64IMAC core in the paper; here we model the
IM-subset the VMM benchmarks exercise, with *real RISC-V instruction
encodings* (decode by bit-slicing, exactly what the functional ISS does) and
a 32-bit datapath (the benchmark arithmetic — int8 activations × int8
weights accumulated over ≤256 products — fits comfortably; documented
simplification of the 64-bit register file).

Memory map (word-addressed bus, byte addresses):
  0x0000_0000 … DRAM (shared main memory, lives in the DRAM segment)
  0x4000_0000 … CIM unit u at 0x4000_0000 + u*0x1000 (see CIM_* offsets)
  0x7000_0000 … per-CPU local scratch SRAM
"""
from __future__ import annotations

# --- opcode constants (RV32 base) ---
OP_LUI = 0b0110111
OP_AUIPC = 0b0010111
OP_JAL = 0b1101111
OP_JALR = 0b1100111
OP_BRANCH = 0b1100011
OP_LOAD = 0b0000011
OP_STORE = 0b0100011
OP_IMM = 0b0010011
OP_REG = 0b0110011

F3_BEQ, F3_BNE, F3_BLT, F3_BGE = 0b000, 0b001, 0b100, 0b101
F3_ADDI = 0b000
F3_ADD = 0b000  # funct7=0 add, 0b0100000 sub, 0b0000001 mul
F3_LW = 0b010
F3_SW = 0b010
F7_MULDIV = 0b0000001

# execution classes (lax.switch indices) produced by the decoder
(
    EX_LUI, EX_AUIPC, EX_JAL, EX_JALR, EX_BRANCH, EX_LOAD, EX_STORE,
    EX_ADDI, EX_ADD, EX_SUB, EX_MUL, EX_ILLEGAL,
) = range(12)

# --- memory map ---
DRAM_BASE = 0x0000_0000
DRAM_WORDS = 1 << 18  # modeled capacity is a VP parameter (128 MB); backing
                      # store sized to the benchmark working set (1 MiB)
CIM_BASE = 0x4000_0000
CIM_STRIDE = 0x1000
SCRATCH_BASE = 0x7000_0000
SCRATCH_WORDS = 1 << 16

# CIM register offsets (byte offsets from unit base) — the unit's
# micro-instruction interface: CONFIG / IN / OP / OUT of the paper's FSM.
CIM_REG_CONFIG = 0x00  # write: {rows[8:0], cols[17:9], in_res[21:18], out_res[25:22]}
CIM_REG_WROW = 0x04  # write: select crossbar row for weight loading
CIM_REG_WDATA = 0x08  # write: next weight word (packs 4 int8 cells)
CIM_REG_INPUT = 0x0C  # write: next input-vector element (starts IN phase)
CIM_REG_START = 0x10  # write: launch OP phase
CIM_REG_STATUS = 0x14  # read: FSM state (0 idle, 1 in, 2 op, 3 out/done)
CIM_REG_OUTPUT = 0x18  # read: next output element (OUT phase)
CIM_REG_MODE = 0x1C  # write: {mode[0], thresh[16:1], leak[24:17], refrac[28:25]}
                     # mode 0 = dense VMM FSM, 1 = spiking (LIF) — the crossbar
                     # becomes a synapse matrix integrating AER spike events.
                     # The register tunes neuron parameters at runtime; tick
                     # scheduling + spike routing (tick_period, dst_*) are
                     # build-time wiring like mgr_seg (segmentation cim_init),
                     # and spikes sent to a unit that never ticks are dropped.
CIM_REG_SPIKE = 0x20  # write: {tick[30:16], axon[15:0]} — inject ONE AER spike
                      # addressed to the unit's LIF tick ``tick`` (the raster
                      # timestep grid: integrated exactly like a pre-scheduled
                      # raster event of timestep ``tick``).  The store does NOT
                      # become a register write: the platform turns it into a
                      # MSG_SPIKE whose t_avail is the tick's grid time, so
                      # CPU-injected spikes ride the tick-bucketed AER
                      # machinery bit-identically under every placement.
                      # Contract: the store must execute at CPU local time
                      # < (tick + 1) * tick_period — later injections are
                      # timing-dependent and trip the loud ``snn_mmio_late``
                      # watermark (vp/platform.py).
CIM_REG_COUNTS = 0x24  # write: request a spike-count readback *as of tick
                       # ``value``* (number of completed LIF ticks).  The unit
                       # serves the request at the first quantum boundary where
                       # its tick counter has reached the target (or it can
                       # never tick again), DMA-ing spike_counts[0:rows] to its
                       # manager's scratch OUT area and writing 1 to its flag
                       # word — the same mailbox protocol as dense completion.
                       # A request the unit has already ticked past is
                       # timing-dependent and trips ``snn_mmio_late``.

CIM_ST_IDLE, CIM_ST_IN, CIM_ST_OP, CIM_ST_OUT = 0, 1, 2, 3

CIM_MODE_DENSE, CIM_MODE_SPIKE = 0, 1


def pack_mode(mode: int, thresh: int = 1, leak: int = 0, refrac: int = 0) -> int:
    """Encode a CIM_REG_MODE register value."""
    return (mode & 1) | (thresh & 0xFFFF) << 1 | (leak & 0xFF) << 17 | (refrac & 0xF) << 25


def pack_spike(tick: int, axon: int) -> int:
    """Encode a CIM_REG_SPIKE store value: one spike for LIF tick ``tick``
    (raster-timestep grid) at crossbar axon ``axon``."""
    return (tick & 0x7FFF) << 16 | (axon & 0xFFFF)


def reg(name: str) -> int:
    """ABI register name -> index."""
    table = {"zero": 0, "ra": 1, "sp": 2, "gp": 3, "tp": 4}
    for i in range(3):
        table[f"t{i}"] = 5 + i
    table["s0"] = 8
    table["s1"] = 9
    for i in range(8):
        table[f"a{i}"] = 10 + i
    for i in range(2, 12):
        table[f"s{i}"] = 16 + i
    for i in range(3, 7):
        table[f"t{i}"] = 25 + i
    return table[name]


def _imm_i(imm):
    return (imm & 0xFFF) << 20


def _imm_s(imm):
    return ((imm >> 5) & 0x7F) << 25 | (imm & 0x1F) << 7


def _imm_b(imm):
    return (
        ((imm >> 12) & 1) << 31
        | ((imm >> 5) & 0x3F) << 25
        | ((imm >> 1) & 0xF) << 8
        | ((imm >> 11) & 1) << 7
    )


def _imm_j(imm):
    return (
        ((imm >> 20) & 1) << 31
        | ((imm >> 1) & 0x3FF) << 21
        | ((imm >> 11) & 1) << 20
        | ((imm >> 12) & 0xFF) << 12
    )


def enc_r(op, rd, f3, rs1, rs2, f7):
    return f7 << 25 | rs2 << 20 | rs1 << 15 | f3 << 12 | rd << 7 | op


def enc_i(op, rd, f3, rs1, imm):
    return _imm_i(imm) | rs1 << 15 | f3 << 12 | rd << 7 | op


def enc_s(op, f3, rs1, rs2, imm):
    return _imm_s(imm) | rs2 << 20 | rs1 << 15 | f3 << 12 | op


def enc_b(op, f3, rs1, rs2, imm):
    return _imm_b(imm) | rs2 << 20 | rs1 << 15 | f3 << 12 | op


def enc_u(op, rd, imm):
    return (imm & 0xFFFFF000) | rd << 7 | op


def enc_j(op, rd, imm):
    return _imm_j(imm) | rd << 7 | op
