"""Fleet-scale SNN serving: batch independent inference jobs through one
device-resident megaloop.

The VP so far runs ONE experiment well: a platform's segments stack under
``vmap`` and the fused megaloop burns through rounds with one host sync per
dispatch (core/controller.py).  Serving traffic is the opposite shape —
thousands of small *independent* requests (NeuroVM's multi-tenant framing:
time-slice the neuromorphic fabric between tenants without leaving the
device; GPU-RANC batches thousands of cores into one vectorized step).  One
request per dispatch would leave the device mostly idle and pay a full host
round-trip per job.

This module adds the *job axis*:

* ``SnnRequest`` — one built platform (cfg, states, pending, meta), e.g.
  from ``snn.workloads.serve_request``.
* ``SnnServer.submit`` — admission queue: stamps arrival time, returns a
  ticket.
* ``SnnServer.flush`` — buckets the queue by compiled shape, pads each
  bucket, and runs it as ONE jitted batched megaloop
  (``controller.job_mega_fn``): per-job termination flags, per-job
  watermarks against each request's own caps, per-job fault seeds and
  trace rings riding in the stacked state.  With a mesh, buckets fan
  across devices via ``shard_map`` (``controller.sharded_job_mega_fn`` +
  ``launch.mesh.make_serve_mesh``).

Bucketing rules (docs/serving.md):

* **Same compiled shape.** Two requests share a bucket iff their configs
  match after *normalization* — the transport fault seed is replaced by 0
  (the seed rides the stacked state, never the compiled program) and the
  channel caps are dropped (they become per-job traced operands).  Static
  fault gates (which fault families exist, their rates, the overflow
  policy) stay in the key: they select compiled code.
* **Cap padding.** A bucket's physical boxes are sized to the bucket
  maximum; each job is judged against its OWN caps by the vmapped
  termination flags, so an overflowing job fails at the same check round
  with the same watermark message as its solo run.  Exception: under
  ``on_overflow="drop"`` capacity *changes deterministic spike loss*, so
  drop-policy requests bucket only with exactly-equal caps (caps stay in
  the key).
* **Padding lanes.** Buckets are padded to a fixed batch size (and to the
  mesh's job-axis multiple) by replicating lane 0 with ``done=True`` —
  frozen from round 0, zero simulated effect.

Results are bit-identical to running each request solo with the same
``check_every`` cadence (tests/test_serve.py proves it across all four
backends and both dispatch paths); a finished job freezes at the first
check round that saw it done — exactly where its solo run stops.
"""
from __future__ import annotations

import dataclasses
import time as _time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as ctl
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class SnnRequest:
    """One admission-ready inference job: a built platform plus its meta.

    ``expected_counts`` is optional oracle output (per output unit) carried
    for end-to-end verification — the server never reads it.
    """
    cfg: object
    states: object
    pending: object
    meta: dict
    expected_counts: tuple | None = None


@dataclasses.dataclass
class SnnResult:
    """Outcome of one served request.

    ``ok=False`` carries the same watermark message the request's solo
    ``Controller.run`` would have raised (per-job caps), or a max_rounds
    exhaustion note.  ``latency_s`` is wall time from ``submit`` to the
    request's bucket completing — the serving latency the p99 metric is
    over, not simulated time.
    """
    request_id: int
    ok: bool
    error: str | None
    rounds: int
    latency_s: float
    states: object
    meta: dict
    events: object = None   # drained telemetry (np EVENT_DTYPE), obs only
    trace_lost: int = 0

    def output_counts(self):
        """Per-output-unit spike counts (topology.output_spike_counts)."""
        from repro.snn import topology as topo

        return topo.output_spike_counts(self.states, self.meta)


def _normalize(cfg):
    """The bucket key: cfg with per-job-able fields factored out."""
    fc = cfg.faults
    if fc is not None:
        fc = dataclasses.replace(fc, seed=0)
    if fc is not None and fc.drop_overflow:
        # capacity changes deterministic spike loss under the drop policy:
        # caps must match exactly, so they stay in the key
        return dataclasses.replace(cfg, faults=fc)
    return dataclasses.replace(cfg, faults=fc,
                               in_cap=0, out_cap=0, store_log=0)


def _pad_pending(pending, cap: int):
    """Grow a (S, cap0) pending box to the bucket's in_cap.

    Freshly padded slots carry channel.empty_pending defaults (zeros,
    valid=False) — dead slots are never read, so this is shape-only.
    """
    cur = pending["valid"].shape[-1]
    if cur == cap:
        return pending
    grow = ((0, 0), (0, cap - cur))
    out = dict(pending)
    for f in ("kind", "addr", "data", "t_avail", "valid"):
        out[f] = jnp.pad(pending[f], grow)
    return out


def _stack(trees):
    return jax.tree.map(lambda *v: jnp.stack(v), *trees)


def _lane(tree, j):
    return jax.tree.map(lambda x: x[j], tree)


class SnnServer:
    """Admission queue + bucketed batch execution for SNN inference jobs.

    ``submit`` is cheap (append + timestamp); all device work happens in
    ``flush``, which serves every queued request and returns
    ``{ticket: SnnResult}``.  ``bucket_size`` caps how many jobs share one
    batched megaloop; larger buckets amortize dispatch overhead but pad
    more when the queue is ragged.  With ``mesh`` (a 1-D "jobs" mesh from
    ``launch.mesh.make_serve_mesh``) each bucket is sharded across the
    mesh devices, so ``bucket_size`` must be a multiple of the mesh size.

    ``check_every`` fixes the termination-check cadence for every bucket —
    the bit-exactness contract is against solo runs at the SAME cadence.
    """

    def __init__(self, *, quantum: int = 10_000, check_every: int = 4,
                 rounds_per_dispatch: int = 256, max_rounds: int = 10_000,
                 bucket_size: int = 8, mesh=None, obs=None):
        if bucket_size < 1:
            raise ValueError("bucket_size must be >= 1")
        if mesh is not None:
            n = int(np.prod(mesh.devices.shape))
            if bucket_size % n:
                raise ValueError(
                    f"bucket_size={bucket_size} must be a multiple of the "
                    f"mesh's {n} devices (shard_map splits the job axis "
                    "evenly)")
        self.quantum = quantum
        self.check_every = check_every
        self.rounds_per_dispatch = rounds_per_dispatch
        self.max_rounds = max_rounds
        self.bucket_size = bucket_size
        self.mesh = mesh
        self.obs = obs
        self.dispatches = 0      # batched megaloop dispatches issued
        self.dispatch_syncs = 0  # host fetches from the serving loop
        self.served = 0          # requests completed over the server's life
        self._queue = []         # (ticket, SnnRequest, t_submit)
        self._next_id = 0
        self._sharded_cache = {}  # (bucket_cfg) -> jitted sharded megaloop

    # -- admission ------------------------------------------------------
    def submit(self, request: SnnRequest) -> int:
        """Queue one request; returns its ticket (key into flush()'s dict)."""
        ticket = self._next_id
        self._next_id += 1
        self._queue.append((ticket, request, _time.perf_counter()))
        return ticket

    def __len__(self):
        return len(self._queue)

    # -- batching -------------------------------------------------------
    def _pad_width(self, n: int) -> int:
        """Lanes per bucket: next power of two (bounds the jit retrace count
        per cfg to log2(bucket_size) batch shapes), or the exact bucket
        size under a mesh (the job axis must split evenly)."""
        if self.mesh is not None:
            return self.bucket_size
        w = 1
        while w < n:
            w *= 2
        return min(w, self.bucket_size)

    def _mega(self, bucket_cfg):
        if self.mesh is None:
            return ctl.job_mega_fn(bucket_cfg, self.quantum, self.obs)
        if bucket_cfg not in self._sharded_cache:
            self._sharded_cache[bucket_cfg] = ctl.sharded_job_mega_fn(
                bucket_cfg, self.mesh, self.quantum, self.obs)
        return self._sharded_cache[bucket_cfg]

    # -- execution ------------------------------------------------------
    def flush(self) -> dict:
        """Serve every queued request; returns ``{ticket: SnnResult}``."""
        results = {}
        queue, self._queue = self._queue, []
        for key_cfg, entries in self._buckets_of(queue):
            results.update(self._run_bucket(key_cfg, entries))
        return results

    def _buckets_of(self, queue):
        """Group by normalized cfg (first-seen order — dict preserves
        insertion; submission order within a group), chunk to
        bucket_size."""
        groups: dict = {}
        for entry in queue:
            groups.setdefault(_normalize(entry[1].cfg), []).append(entry)
        for key_cfg, entries in groups.items():
            for i in range(0, len(entries), self.bucket_size):
                yield key_cfg, entries[i:i + self.bucket_size]

    def _run_bucket(self, key_cfg, entries):
        reqs = [e[1] for e in entries]
        bucket_cfg = dataclasses.replace(
            key_cfg,
            in_cap=max(r.cfg.in_cap for r in reqs),
            out_cap=max(r.cfg.out_cap for r in reqs),
            store_log=max(r.cfg.store_log for r in reqs),
        )
        n = len(entries)
        width = self._pad_width(n)

        def prep(req):
            st = req.states
            if self.obs is not None and "trace" not in st:
                cap = int(self.obs.capacity)
                st = {**st, "trace": jax.vmap(
                    lambda _: obs_trace.ring_state(cap))(
                        jnp.arange(bucket_cfg.n_segments))}
            return st, _pad_pending(req.pending, bucket_cfg.in_cap)

        lanes = [prep(r) for r in reqs]
        lanes += [lanes[0]] * (width - n)  # inert padding lanes (done0=True)
        states = _stack([l[0] for l in lanes])
        pending = _stack([l[1] for l in lanes])

        pad = lambda vals: jnp.asarray(
            list(vals) + [vals[0]] * (width - n), jnp.int32)
        in_cap = pad([r.cfg.in_cap for r in reqs])
        out_cap = pad([r.cfg.out_cap for r in reqs])
        store_log = pad([r.cfg.store_log for r in reqs])

        rounds = jnp.zeros((width,), jnp.int32)
        done = jnp.arange(width) >= n   # padding lanes frozen from round 0
        over = jnp.zeros((width,), bool)
        mega = self._mega(bucket_cfg)

        per_job_events = [[] for _ in range(n)]
        per_job_lost = [0] * n
        ran = 0
        while ran < self.max_rounds:
            k = min(self.rounds_per_dispatch, self.max_rounds - ran)
            states, pending, rounds, done, over = mega(
                states, pending, rounds, done, over,
                in_cap, out_cap, store_log,
                jnp.int32(ran), jnp.int32(k), jnp.int32(self.check_every))
            self.dispatches += 1
            self.dispatch_syncs += 1
            # one host sync per dispatch — scalars and the telemetry rings
            # come back in a single transfer, like Controller.run
            if self.obs is None:
                rounds_h, done_h, over_h = ctl._HOST_FETCH(
                    (rounds, done, over))
            else:
                rounds_h, done_h, over_h, ring = ctl._HOST_FETCH(
                    (rounds, done, over, states["trace"]))
                for j in range(n):
                    ev, lost = obs_trace.drain(_lane(ring, j))
                    per_job_lost[j] += lost
                    if len(ev):
                        per_job_events[j].append(ev)
                states = {**states,
                          "trace": obs_trace.reset(states["trace"])}
            prev, ran = ran, int(rounds_h.max())
            if (done_h | over_h).all() or ran == prev:
                break
        t_done = _time.perf_counter()

        out = {}
        for j, (ticket, req, t_submit) in enumerate(entries):
            st_j, pen_j = _lane(states, j), _lane(pending, j)
            error = None
            if bool(over_h[j]) or not bool(done_h[j]):
                drop = (req.cfg.faults is not None
                        and req.cfg.faults.drop_overflow)
                error = ctl.overflow_error(
                    st_j, pen_j, in_cap=req.cfg.in_cap,
                    out_cap=req.cfg.out_cap, store_log=req.cfg.store_log,
                    drop=drop)
                if error is None:
                    error = (f"max_rounds={self.max_rounds} exhausted "
                             "before termination")
            events = (np.concatenate(per_job_events[j])
                      if per_job_events[j] else
                      np.empty(0, obs_trace.EVENT_DTYPE))
            out[ticket] = SnnResult(
                request_id=ticket, ok=error is None, error=error,
                rounds=int(rounds_h[j]), latency_s=t_done - t_submit,
                states=st_j, meta=req.meta, events=events,
                trace_lost=per_job_lost[j])
            self.served += 1
        return out
