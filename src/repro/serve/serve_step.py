"""Serving steps: prefill and single-token decode over a batched KV cache.

The decode path assumes aligned continuous batching (all slots advance one
position per step — the vLLM-style fixed-step regime); the cache layout and
sharding come from ``models.model.cache_specs`` (batch over data axes, kv
heads over model when divisible, sequence over leftover axes => split-KV
decode for long-context / MQA shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill(model, mesh=None):
    def prefill(params, batch):
        return model.prefill(params, batch, mesh=mesh)

    return prefill


def make_decode_step(model, mesh=None):
    def decode_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos, mesh=mesh)

    return decode_step


def greedy_generate(model, params, batch, steps: int, mesh=None, pad_to: int | None = None):
    """Simple greedy loop for examples/tests: prefill then `steps` decode steps."""
    cache, lg = model.prefill(params, batch, mesh=mesh)
    seq = batch["tokens"].shape[1]
    if pad_to:
        def pad_seq(x):
            if x.ndim >= 4 and x.shape[-3] == seq:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, pad_to - seq)
                return jnp.pad(x, pad)
            return x

        cache = jax.tree.map(pad_seq, cache)
    toks = [jnp.argmax(lg[:, -1], axis=-1)]
    b = batch["tokens"].shape[0]

    @jax.jit
    def step(params, cache, db, pos):
        return model.decode_step(params, cache, db, pos, mesh=mesh)

    for i in range(steps - 1):
        db = {"tokens": toks[-1][:, None]}
        if model.cfg.mrope:
            db["mrope_pos"] = jnp.full((3, b, 1), seq + i, jnp.int32)
        lg, cache = step(params, cache, db, jnp.int32(seq + i))
        toks.append(jnp.argmax(lg[:, -1], axis=-1))
    return jnp.stack(toks, axis=1)
