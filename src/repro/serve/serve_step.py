"""Serving steps: prefill and single-token decode over a batched KV cache.

The decode path assumes aligned continuous batching (all slots advance one
position per step — the vLLM-style fixed-step regime); the cache layout and
sharding come from ``models.model.cache_specs`` (batch over data axes, kv
heads over model when divisible, sequence over leftover axes => split-KV
decode for long-context / MQA shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill(model, mesh=None):
    def prefill(params, batch):
        return model.prefill(params, batch, mesh=mesh)

    return prefill


def make_decode_step(model, mesh=None):
    def decode_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos, mesh=mesh)

    return decode_step


def cache_seq_axes(cfg, cache, seq: int, batch: int):
    """Per-leaf index of the sequence axis in a decode cache, or None for
    leaves that are not sequence-addressed.

    Derived from ``models.model.cache_specs`` — the layout's single source
    of truth — instead of shape matching: the specs are probed at two
    sequence lengths (``kind="decode"``, so an encdec cross cache keeps its
    fixed ``n_audio_frames`` memory length) and the axis whose size moved
    is the sequence axis.  Shape heuristics are wrong exactly when an
    unrelated axis collides with the prompt length: an SSM conv/state cell
    ``(n_stack, B, d, N)`` has the *batch* axis at the position a KV cell
    keeps its sequence axis, so ``batch == prompt_len`` made the old
    ``x.shape[-3] == seq`` test pad the batch (regression-pinned in
    tests/test_serve.py).
    """
    from repro.configs.base import ShapeConfig
    from repro.models.model import cache_specs

    def probe(s):
        sds, _ = cache_specs(cfg, ShapeConfig("probe", s, batch, "decode"))
        return jax.tree.leaves(sds)

    lo, hi = probe(seq), probe(seq + 1)
    leaves = jax.tree.leaves(cache)
    assert len(lo) == len(leaves), (
        f"cache_specs tree ({len(lo)} leaves) does not match the live "
        f"decode cache ({len(leaves)} leaves)")
    axes = []
    for la, lb, leaf in zip(lo, hi, leaves):
        assert la.ndim == lb.ndim == leaf.ndim
        moved = [i for i, (a, b) in enumerate(zip(la.shape, lb.shape))
                 if a != b]
        assert len(moved) <= 1, (la.shape, lb.shape)
        axes.append(moved[0] if moved else None)
    return axes


def greedy_generate(model, params, batch, steps: int, mesh=None, pad_to: int | None = None):
    """Simple greedy loop for examples/tests: prefill then `steps` decode steps."""
    cache, lg = model.prefill(params, batch, mesh=mesh)
    seq = batch["tokens"].shape[1]
    if pad_to:
        axes = cache_seq_axes(model.cfg, cache, seq,
                              batch["tokens"].shape[0])
        flat, treedef = jax.tree.flatten(cache)

        def pad_seq(x, ax):
            if ax is None or x.shape[ax] >= pad_to:
                return x
            pad = [(0, 0)] * x.ndim
            pad[ax] = (0, pad_to - x.shape[ax])
            return jnp.pad(x, pad)

        cache = jax.tree.unflatten(
            treedef, [pad_seq(x, ax) for x, ax in zip(flat, axes)])
    toks = [jnp.argmax(lg[:, -1], axis=-1)]
    b = batch["tokens"].shape[0]

    @jax.jit
    def step(params, cache, db, pos):
        return model.decode_step(params, cache, db, pos, mesh=mesh)

    for i in range(steps - 1):
        db = {"tokens": toks[-1][:, None]}
        if model.cfg.mrope:
            db["mrope_pos"] = jnp.full((3, b, 1), seq + i, jnp.int32)
        lg, cache = step(params, cache, db, jnp.int32(seq + i))
        toks.append(jnp.argmax(lg[:, -1], axis=-1))
    return jnp.stack(toks, axis=1)
