"""Pallas TPU kernel: chunked selective scan (Mamba-1 recurrence).

Grid: (batch, d_blocks, seq_chunks) with the sequence dimension iterated
*sequentially* (minor-most grid dim on TPU runs on the same core), carrying
the (D_BLOCK, N) state in a VMEM scratch accumulator across chunk steps —
the canonical TPU accumulator pattern.  Within a chunk, a ``fori_loop``
advances the recurrence step by step entirely in VMEM: the (S, D, N)
decay/drive tensors stream through HBM exactly once, instead of the ~4
materialized round-trips of the jnp formulation (the falcon-mamba train
cell's memory-bound roofline term — see EXPERIMENTS.md §Perf).

Validated in interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

D_BLOCK = 128
CHUNK = 64


def _kernel(da_ref, dbx_ref, c_ref, y_ref, h_ref, *, chunk: int):
    sc = pl.program_id(2)

    @pl.when(sc == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    da = da_ref[...]  # (1, chunk, D_BLOCK, N)
    dbx = dbx_ref[...]
    c = c_ref[...]  # (1, chunk, N)

    def body(t, carry):
        h = carry
        h = da[0, t] * h + dbx[0, t]
        y = (h * c[0, t][None, :]).sum(axis=1)  # (D_BLOCK,)
        y_ref[0, t, :] = y
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan(da, dbx, c, interpret: bool = True):
    """da, dbx (B, S, D, N) fp32; c (B, S, N) fp32 -> y (B, S, D) fp32.

    h0 = 0 (prefill/train); decode uses the O(1) jnp path instead.
    """
    b, s, d, n = da.shape
    assert s % CHUNK == 0 and d % D_BLOCK == 0, (s, d)
    grid = (b, d // D_BLOCK, s // CHUNK)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=CHUNK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, D_BLOCK, n), lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, CHUNK, D_BLOCK, n), lambda bi, di, si: (bi, si, di, 0)),
            pl.BlockSpec((1, CHUNK, n), lambda bi, di, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK, D_BLOCK), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((D_BLOCK, n), jnp.float32)],
        interpret=interpret,
    )(da, dbx, c)
