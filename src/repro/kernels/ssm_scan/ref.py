"""Pure-jnp oracle for the selective-scan (Mamba-1) recurrence.

  h_t = da_t * h_{t-1} + dbx_t          (elementwise over (D, N))
  y_t = sum_n h_t[d, n] * c_t[n]

Shapes: da, dbx (B, S, D, N); c (B, S, N); h0 (B, D, N) -> y (B, S, D), h_S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan(da, dbx, c, h0):
    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0), jnp.moveaxis(c, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
