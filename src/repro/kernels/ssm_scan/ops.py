"""Jit'd public wrapper for the selective-scan kernel."""
from __future__ import annotations

from repro.kernels.ssm_scan.kernel import ssm_scan as _ssm_scan

INTERPRET = True  # CPU container


def ssm_scan(da, dbx, c):
    """da, dbx (B, S, D, N); c (B, S, N) -> y (B, S, D); h0 = 0."""
    return _ssm_scan(da, dbx, c, interpret=INTERPRET)
