"""Jit'd public wrappers for the crossbar VMM kernel.

``interpret=True`` on this CPU container (kernel body executed by the Pallas
interpreter, semantics identical); on a real TPU deployment flip the flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.crossbar_vmm.kernel import crossbar_vmm_tiles

INTERPRET = True  # CPU container: no TPU lowering available


def crossbar_vmm(weights, x, in_res: int = 8, out_res: int = 8,
                 f_and=None, f_xor=None):
    """weights int8 (R, C); x int32 (C,) -> int32 (R,); optional crossbar
    fault masks f_and/f_xor int8 (R, C) (repro.faults)."""
    return crossbar_vmm_tiles(x[None, :], weights, in_res, out_res,
                              f_and, f_xor, interpret=INTERPRET)[0]


def crossbar_vmm_batch(weights, x, in_res: int = 8, out_res: int = 8,
                       f_and=None, f_xor=None):
    """Batched over units: weights (U, R, C) int8; x (U, C) int32 -> (U, R).

    ``f_and``/``f_xor`` (int8 (U, R, C), optional): per-unit crossbar fault
    masks — None keeps the unfaulted kernel byte-identical.

    Used by the CIM quantum-boundary completion (vp/cim.py) when the
    platform is built with ``use_kernel=True``.
    """
    return jax.vmap(
        lambda w, v, a, f: crossbar_vmm(w, v, in_res, out_res, a, f)
    )(weights, x, f_and, f_xor)


def crossbar_matmul(weights, x, in_res: int = 8, out_res: int = 8):
    """weights (R, C) int8, x (C, N) int32 -> (R, N) — tiled GEMM form."""
    return crossbar_vmm_tiles(x.T, weights, in_res, out_res, interpret=INTERPRET).T
