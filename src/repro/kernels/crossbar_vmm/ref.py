"""Pure-jnp oracle for the bit-sliced crossbar VMM.

Semantics (paper Fig. 1b/1c, CIM-Unit calculator):
  1. DAC: clamp the input vector to ``in_res`` signed bits, then split it
     into ``in_res``-worth of bit-serial slices (sign-magnitude: the sign is
     applied after magnitude accumulation, matching differential crossbar
     pairs);
  2. crossbar MAC: each slice drives the memristor array -> int matvec
     against int8 conductances;
  3. S+H / shift-add: partial results accumulate weighted by 2^k;
  4. ADC: saturate to ``out_res`` signed bits + log2(C) accumulation
     headroom (fixed full-scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_dac(x, in_res: int):
    lo = -(1 << (in_res - 1))
    hi = (1 << (in_res - 1)) - 1
    return jnp.clip(x, lo, hi)


def bit_slices(mag, in_res: int):
    """Unsigned magnitude -> list of 0/1 planes, LSB first."""
    return [((mag >> k) & 1) for k in range(in_res)]


def adc_saturate(acc, out_res: int, headroom_bits: int = 8):
    hi = (1 << (out_res - 1 + headroom_bits)) - 1
    return jnp.clip(acc, -hi - 1, hi)


def crossbar_vmm(weights, x, in_res: int = 8, out_res: int = 8,
                 f_and=None, f_xor=None):
    """weights int8 (R, C); x int32 (C,) -> int32 (R,).

    Bit-exact model of the analog pipeline: identical result to
    ``clip(W @ clip(x))`` because the bit-serial accumulation is exact —
    the decomposition is still modeled explicitly so the kernel and the
    oracle share structure (and tests can probe per-slice equivalence).

    ``f_and`` / ``f_xor`` (int8 (R, C), optional) are the crossbar fault
    masks (repro.faults): the array drives ``(w & f_and) ^ f_xor`` — the
    read-time view of stuck-at / bit-flip / row / column failures.
    """
    if f_and is not None:
        weights = (weights & f_and) ^ f_xor
    xq = quantize_dac(x, in_res)
    sign = jnp.sign(xq).astype(jnp.int32)
    mag = jnp.abs(xq).astype(jnp.int32)
    w = weights.astype(jnp.int32)
    acc = jnp.zeros((weights.shape[0],), jnp.int32)
    for k, plane in enumerate(bit_slices(mag, in_res)):
        acc = acc + ((w @ (plane * sign)) << k)
    return adc_saturate(acc, out_res)


def crossbar_vmm_batch(weights, x, in_res: int = 8, out_res: int = 8,
                       f_and=None, f_xor=None):
    """weights (U, R, C) int8; x (U, C) int32 -> (U, R) int32; optional
    per-unit fault masks f_and/f_xor int8 (U, R, C)."""
    return jax.vmap(
        lambda w, v, a, f: crossbar_vmm(w, v, in_res, out_res, a, f)
    )(weights, x, f_and, f_xor)


def crossbar_matmul(weights, x, in_res: int = 8, out_res: int = 8):
    """Tiled matrix version: weights (R, C) int8, x (C, N) int32 -> (R, N)."""
    return jax.vmap(lambda col: crossbar_vmm(weights, col, in_res, out_res), in_axes=1, out_axes=1)(x)
