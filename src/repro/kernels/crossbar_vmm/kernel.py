"""Pallas TPU kernel: bit-sliced memristor-crossbar VMM.

TPU adaptation of the analog pipeline (DESIGN.md §2): the 256×256 crossbar
maps onto 2×2 MXU-aligned 128×128 tiles held in VMEM; the DAC's bit-serial
drive becomes ``in_res`` per-slice int matmuls accumulated with shift-add in
an fp32/int32 VMEM scratch; the ADC is a saturating clamp on the way out.

Grid: (batch_tiles, row_tiles) — each program instance owns a (TILE_B,
TILE_R) block of outputs and loops the full contraction (C) and the bit
slices in registers/VMEM.  Block shapes are multiples of (8, 128) so both
the MXU contraction (K = C) and the lane dimension stay hardware-aligned.

Validated in interpret mode against ref.py (tests/test_kernels.py sweeps
shapes, resolutions and dtypes with hypothesis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 8  # batch (input vectors) per program
TILE_R = 128  # output rows per program


def _kernel(x_ref, w_ref, o_ref, *, in_res: int, out_res: int):
    """x (TILE_B, C) int32; w (C, TILE_R) int8 -> o (TILE_B, TILE_R) int32."""
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    lo = -(1 << (in_res - 1))
    hi = (1 << (in_res - 1)) - 1
    xq = jnp.clip(x, lo, hi)
    sign = jnp.sign(xq).astype(jnp.float32)
    mag = jnp.abs(xq)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for k in range(in_res):  # bit-serial DAC drive
        plane = ((mag >> k) & 1).astype(jnp.float32) * sign
        # MXU matmul per slice; shift-add (S+H) accumulation
        acc = acc + jax.lax.dot(plane, w, preferred_element_type=jnp.float32) * float(1 << k)
    hi_out = float((1 << (out_res - 1 + 8)) - 1)
    acc = jnp.clip(acc, -hi_out - 1.0, hi_out)  # ADC saturation
    o_ref[...] = acc.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("in_res", "out_res", "interpret"))
def crossbar_vmm_tiles(x, weights, in_res: int = 8, out_res: int = 8, interpret: bool = True):
    """x (B, C) int32, weights int8 (R, C) -> (B, R) int32.

    B and R are padded to tile multiples; C (the contraction) stays whole —
    a 256-deep contraction fits VMEM comfortably (256×128 int8 = 32 KB/tile).
    """
    b, c = x.shape
    r = weights.shape[0]
    bp = -(-b // TILE_B) * TILE_B
    rp = -(-r // TILE_R) * TILE_R
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    wp = jnp.pad(weights, ((0, rp - r), (0, 0))).T  # (C, Rp)

    grid = (bp // TILE_B, rp // TILE_R)
    out = pl.pallas_call(
        functools.partial(_kernel, in_res=in_res, out_res=out_res),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, TILE_R), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_R), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, rp), jnp.int32),
        interpret=interpret,
    )(xp, wp)
    return out[:b, :r]
