"""Pallas TPU kernel: bit-sliced memristor-crossbar VMM.

TPU adaptation of the analog pipeline (DESIGN.md §2): the 256×256 crossbar
maps onto 2×2 MXU-aligned 128×128 tiles held in VMEM; the DAC's bit-serial
drive becomes ``in_res`` per-slice int matmuls accumulated with shift-add in
an fp32/int32 VMEM scratch; the ADC is a saturating clamp on the way out.

Grid: (batch_tiles, row_tiles) — each program instance owns a (TILE_B,
TILE_R) block of outputs and loops the full contraction (C) and the bit
slices in registers/VMEM.  Block shapes are multiples of (8, 128) so both
the MXU contraction (K = C) and the lane dimension stay hardware-aligned.

Validated in interpret mode against ref.py (tests/test_kernels.py sweeps
shapes, resolutions and dtypes with hypothesis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 8  # batch (input vectors) per program
TILE_R = 128  # output rows per program


def _kernel(x_ref, w_ref, o_ref, *, in_res: int, out_res: int):
    """x (TILE_B, C) int32; w (C, TILE_R) int8 -> o (TILE_B, TILE_R) int32."""
    x = x_ref[...]
    w = w_ref[...].astype(jnp.float32)
    _vmm_body(x, w, o_ref, in_res, out_res)


def _kernel_faults(x_ref, w_ref, a_ref, f_ref, o_ref, *, in_res: int,
                   out_res: int):
    """Fault-injecting variant (repro.faults): the crossbar reads through
    the AND/XOR masks — ``(w & a) ^ f`` in int8 before the fp32 promotion —
    modeling stuck-at / bit-flip / row / column failures at read time.
    a/f (C, TILE_R) int8; neutral masks (a = -1, f = 0) reproduce
    ``_kernel`` bit-exactly."""
    x = x_ref[...]
    w = ((w_ref[...] & a_ref[...]) ^ f_ref[...]).astype(jnp.float32)
    _vmm_body(x, w, o_ref, in_res, out_res)


def _vmm_body(x, w, o_ref, in_res: int, out_res: int):
    lo = -(1 << (in_res - 1))
    hi = (1 << (in_res - 1)) - 1
    xq = jnp.clip(x, lo, hi)
    sign = jnp.sign(xq).astype(jnp.float32)
    mag = jnp.abs(xq)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for k in range(in_res):  # bit-serial DAC drive
        plane = ((mag >> k) & 1).astype(jnp.float32) * sign
        # MXU matmul per slice; shift-add (S+H) accumulation
        acc = acc + jax.lax.dot(plane, w, preferred_element_type=jnp.float32) * float(1 << k)
    hi_out = float((1 << (out_res - 1 + 8)) - 1)
    acc = jnp.clip(acc, -hi_out - 1.0, hi_out)  # ADC saturation
    o_ref[...] = acc.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("in_res", "out_res", "interpret"))
def crossbar_vmm_tiles(x, weights, in_res: int = 8, out_res: int = 8,
                       f_and=None, f_xor=None, interpret: bool = True):
    """x (B, C) int32, weights int8 (R, C) -> (B, R) int32.

    B and R are padded to tile multiples; C (the contraction) stays whole —
    a 256-deep contraction fits VMEM comfortably (256×128 int8 = 32 KB/tile).

    ``f_and`` / ``f_xor`` (int8 (R, C), optional, repro.faults): crossbar
    read-time fault masks, padded and transposed exactly like the weights;
    None runs the unfaulted kernel unchanged.
    """
    b, c = x.shape
    r = weights.shape[0]
    bp = -(-b // TILE_B) * TILE_B
    rp = -(-r // TILE_R) * TILE_R
    xp = jnp.pad(x, ((0, bp - b), (0, 0)))
    pad_w = lambda w: jnp.pad(w, ((0, rp - r), (0, 0))).T  # (C, Rp)
    wp = pad_w(weights)

    grid = (bp // TILE_B, rp // TILE_R)
    w_spec = pl.BlockSpec((c, TILE_R), lambda i, j: (0, j))
    in_specs = [pl.BlockSpec((TILE_B, c), lambda i, j: (i, 0)), w_spec]
    operands = [xp, wp]
    kernel = _kernel
    if f_and is not None:
        kernel = _kernel_faults
        in_specs += [w_spec, w_spec]
        operands += [pad_w(f_and), pad_w(f_xor)]
    out = pl.pallas_call(
        functools.partial(kernel, in_res=in_res, out_res=out_res),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((TILE_B, TILE_R), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, rp), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:b, :r]
