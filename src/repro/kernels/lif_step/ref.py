"""Pure-jnp oracle for the fused LIF neuron-pool step.

One SNN tick of a crossbar-backed neuron pool (the spike-mode CIM unit's
"calculator"), fusing four stages that the Pallas kernel executes in one
VMEM-resident pass:

  1. synaptic accumulation: the int8 synapse matrix (crossbar conductances)
     contracts the incoming spike-count vector -> per-neuron current;
  2. leak: subtractive integer leak, membrane floor-clamped at 0
     (TrueNorth/RANC-style positive-saturating LIF);
  3. threshold: neurons out of refractory period with v >= thresh fire;
  4. reset + refractory: fired neurons reset to 0 and load the refractory
     counter; everyone else's counter decays toward 0.

All arithmetic is int32-exact, so the kernel, this oracle, and the SNN
subsystem oracle (snn/neuron.py delegates here) are bit-identical — the
same property tests/test_snn.py asserts across controller backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


SPIKE_SAT = 511  # per-axon per-tick fan-in saturation (9 bits): keeps
                 # |W·s| <= 256·127·511 < 2^24, so the kernel's fp32 MXU
                 # contraction stays integer-exact and bit-equal to this
                 # int32 oracle (the AER analogue of the DAC input clamp)


def syn_charge(weights, spikes, f_and=None, f_xor=None):
    """Synaptic accumulation alone: int8 (R, C) crossbar × int32 (C,) spike
    counts -> int32 (R,) charge, with the same fan-in saturation the fused
    step applies.  Column tiles of a multi-crossbar layer compute this and
    forward it to the stripe owner (vp/cim.py snn_tick); because the clip is
    element-wise and the int32 contraction distributes over column blocks,
    the tiled sum is bit-identical to one full-width contraction.

    ``f_and`` / ``f_xor`` (int8 (R, C), optional) are the crossbar fault
    masks (repro.faults): the contraction reads ``(w & f_and) ^ f_xor``
    instead of ``w``, so stuck/flipped cells fault at *read* time and
    reprogramming the row cannot heal them.
    """
    if f_and is not None:
        weights = (weights & f_and) ^ f_xor
    spikes = jnp.clip(spikes, -SPIKE_SAT, SPIKE_SAT)
    return weights.astype(jnp.int32) @ spikes.astype(jnp.int32)


def lif_update(syn, v, refrac, thresh, leak, refrac_period,
               dead=None, dth=None):
    """Post-contraction LIF stages (leak / threshold / reset / refractory)
    on a precomputed charge vector ``syn`` int32 (R,).  Split out so callers
    that already hold the charge — the grouped spike-mode tick sums column
    tiles' partial contractions — never pay the synapse matmul twice.

    Neuron faults (repro.faults, optional): ``dead`` bool (R,) pins a
    neuron's membrane to 0 and gates it out of integration and firing;
    ``dth`` int32 (R,) drifts the firing threshold per neuron (effective
    threshold clamped >= 1, mirroring the CIM_REG_MODE clamp)."""
    active = refrac == 0
    if dead is not None:
        active = active & ~dead
    th_eff = thresh if dth is None else jnp.maximum(thresh + dth, 1)
    v1 = jnp.maximum(v + jnp.where(active, syn, 0) - leak, 0)
    fired = active & (v1 >= th_eff)
    v_out = jnp.where(fired, 0, v1)
    if dead is not None:
        v_out = jnp.where(dead, 0, v_out)
    refrac_out = jnp.where(fired, refrac_period, jnp.maximum(refrac - 1, 0))
    return v_out, refrac_out, fired.astype(jnp.int32)


def lif_step(weights, spikes, v, refrac, thresh, leak, refrac_period,
             extra=None, f_and=None, f_xor=None, dead=None, dth=None):
    """weights int8 (R, C); spikes int32 (C,); v/refrac int32 (R,);
    thresh/leak/refrac_period int32 scalars -> (v', refrac', fired int32 (R,)).

    ``extra`` (int32 (R,), optional) is additional synaptic charge summed
    into the accumulation stage — the merged contribution of a wide layer's
    other column tiles.  It obeys the same refractory gate as the local
    crossbar's charge.

    ``f_and``/``f_xor``/``dead``/``dth`` are the optional fault-injection
    inputs (see ``syn_charge`` / ``lif_update``); None compiles them out.
    """
    syn = syn_charge(weights, spikes, f_and, f_xor)
    if extra is not None:
        syn = syn + extra
    return lif_update(syn, v, refrac, thresh, leak, refrac_period, dead, dth)


def lif_step_units(weights, spikes, v, refrac, thresh, leak, refrac_period,
                   extra=None, f_and=None, f_xor=None, dead=None, dth=None):
    """Batched over units: weights (U, R, C) int8; spikes (U, C) int32;
    v/refrac (U, R) int32; thresh/leak/refrac_period (U,) int32;
    extra (U, R) int32 or None; fault inputs (repro.faults, optional):
    f_and/f_xor int8 (U, R, C), dead bool (U, R), dth int32 (U, R)."""
    # None arguments are empty pytrees: vmap maps the present arrays and
    # passes None through, so every optional combination shares this path
    return jax.vmap(lif_step)(weights, spikes, v, refrac, thresh, leak,
                              refrac_period, extra, f_and, f_xor, dead, dth)
