"""Jit'd public wrappers for the fused LIF step kernel.

``interpret=True`` on this CPU container (kernel body executed by the Pallas
interpreter, semantics identical); on a real TPU deployment flip the flag.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lif_step.kernel import lif_step_tiles

INTERPRET = True  # CPU container: no TPU lowering available


def lif_step_units(weights, spikes, v, refrac, thresh, leak, refrac_period,
                   extra=None, f_and=None, f_xor=None, dead=None, dth=None):
    """Batched over units: weights (U, R, C) int8; spikes (U, C) int32;
    v/refrac (U, R) int32; thresh/leak/refrac_period (U,) int32;
    extra (U, R) int32 or None (merged charge from a wide layer's other
    column tiles) -> (v', refrac', fired) each (U, R) int32.

    ``f_and``/``f_xor``/``dead``/``dth`` are the optional fault-injection
    inputs (repro.faults; see kernel.py) — None selects the unfaulted
    kernel unchanged.

    Used by the spike-mode CIM tick (vp/cim.py) when the platform is built
    with ``use_kernel=True``.
    """
    return lif_step_tiles(weights, spikes, v, refrac, thresh, leak,
                          refrac_period, extra, f_and, f_xor, dead, dth,
                          interpret=INTERPRET)


def lif_step(weights, spikes, v, refrac, thresh, leak, refrac_period):
    """Single pool: weights (R, C) int8, spikes (C,), v/refrac (R,), scalars."""
    to1 = lambda x: jnp.asarray(x, jnp.int32)[None]
    v2, r2, f2 = lif_step_units(
        weights[None], spikes[None], v[None], refrac[None],
        to1(thresh), to1(leak), to1(refrac_period),
    )
    return v2[0], r2[0], f2[0]
