"""Pallas TPU kernel: fused LIF neuron-pool step.

One program instance owns one unit's (TILE_R)-neuron block: the synaptic
contraction (spike-count vector × int8 synapse tile) runs on the MXU in
fp32 (spike counts ≤ fan-in and |w| ≤ 127 keep the accumulator far inside
fp32's exact-integer range), then leak / threshold / reset / refractory all
happen element-wise on the VPU without the membrane state ever leaving
VMEM.  This fusion is the point: the eager formulation materialises three
(U, R) intermediates per tick; the kernel writes only the new state.

Grid: (units, row_tiles).  Weights arrive pre-transposed (U, C, R) so the
contraction is a plain (1, C) × (C, TILE_R) dot per block.  Per-unit LIF
parameters (thresh/leak/refrac_period) ride along as length-1 blocks.

Validated in interpret mode against ref.py (tests/test_snn.py sweeps shapes
and parameters; int32-exactness makes equality bit-strict).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lif_step.ref import SPIKE_SAT

TILE_R = 128  # neurons per program (lane-aligned)


def _kernel(s_ref, w_ref, x_ref, v_ref, r_ref, th_ref, lk_ref, rp_ref,
            vo_ref, ro_ref, so_ref):
    """s (1, C) int32; w (1, C, TILE_R) int8; x (1, TILE_R) int32 extra
    charge; v/r (1, TILE_R) int32; th/lk/rp (1,) int32
    -> v'/r'/fired (1, TILE_R) int32."""
    # fan-in saturation (mirrors ref.py): bounds the accumulator inside
    # fp32's exact-integer range so the MXU contraction never rounds
    s = jnp.clip(s_ref[...], -SPIKE_SAT, SPIKE_SAT).astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)  # (C, TILE_R)
    syn = jax.lax.dot(s, w, preferred_element_type=jnp.float32).astype(jnp.int32)
    # extra charge from the layer's other column tiles (wide multi-crossbar
    # layers): already int32-exact, summed after the local contraction
    syn = syn + x_ref[...]
    v = v_ref[...]
    refrac = r_ref[...]
    thresh, leak, rp = th_ref[0], lk_ref[0], rp_ref[0]
    active = refrac == 0
    v1 = jnp.maximum(v + jnp.where(active, syn, 0) - leak, 0)
    fired = active & (v1 >= thresh)
    vo_ref[...] = jnp.where(fired, 0, v1)
    ro_ref[...] = jnp.where(fired, rp, jnp.maximum(refrac - 1, 0))
    so_ref[...] = fired.astype(jnp.int32)


def _kernel_faults(s_ref, w_ref, a_ref, f_ref, x_ref, v_ref, r_ref, th_ref,
                   lk_ref, rp_ref, dd_ref, dt_ref, vo_ref, ro_ref, so_ref):
    """Fault-injecting variant of ``_kernel`` (repro.faults): the crossbar
    reads through the AND/XOR masks — ``(w & a) ^ f`` in int8 before the
    fp32 promotion — dead lanes (dd != 0) are gated out of integration and
    firing with the membrane pinned to 0, and the threshold drifts per
    neuron (``max(th + dt, 1)``).  Same VMEM-resident fusion; neutral
    masks (a = -1, f = 0, dd = dt = 0) reproduce ``_kernel`` bit-exactly,
    which is what lets one variant serve every fault-family combination.

    a/f (1, C, TILE_R) int8; dd/dt (1, TILE_R) int32; rest as ``_kernel``.
    """
    s = jnp.clip(s_ref[...], -SPIKE_SAT, SPIKE_SAT).astype(jnp.float32)
    w = ((w_ref[0] & a_ref[0]) ^ f_ref[0]).astype(jnp.float32)  # (C, TILE_R)
    syn = jax.lax.dot(s, w, preferred_element_type=jnp.float32).astype(jnp.int32)
    syn = syn + x_ref[...]
    v = v_ref[...]
    refrac = r_ref[...]
    thresh, leak, rp = th_ref[0], lk_ref[0], rp_ref[0]
    dead = dd_ref[...] != 0
    active = (refrac == 0) & ~dead
    th_eff = jnp.maximum(thresh + dt_ref[...], 1)
    v1 = jnp.maximum(v + jnp.where(active, syn, 0) - leak, 0)
    fired = active & (v1 >= th_eff)
    vo_ref[...] = jnp.where(dead, 0, jnp.where(fired, 0, v1))
    ro_ref[...] = jnp.where(fired, rp, jnp.maximum(refrac - 1, 0))
    so_ref[...] = fired.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lif_step_tiles(weights, spikes, v, refrac, thresh, leak, refrac_period,
                   extra=None, f_and=None, f_xor=None, dead=None, dth=None,
                   interpret: bool = True):
    """weights (U, R, C) int8; spikes (U, C) int32; v/refrac (U, R) int32;
    thresh/leak/refrac_period (U,) int32; extra (U, R) int32 or None
    -> (v', refrac', fired) each (U, R).

    R is padded to the tile multiple; C (the contraction) stays whole — a
    256-deep fan-in fits VMEM comfortably (256×128 int8 = 32 KB/tile).

    Fault inputs (repro.faults, all optional): f_and/f_xor int8 (U, R, C)
    crossbar read masks, dead bool (U, R), dth int32 (U, R).  When any is
    given the fault kernel variant runs with neutral values substituted
    for the absent ones (bit-identical semantics for those stages); when
    all are None the original kernel runs untouched.
    """
    u, r, c = weights.shape
    rp_pad = -(-r // TILE_R) * TILE_R
    pad_w = lambda x: jnp.pad(
        x, ((0, 0), (0, rp_pad - r), (0, 0))).transpose(0, 2, 1)  # (U, C, Rp)
    wt = pad_w(weights)
    pad_r = lambda x: jnp.pad(x, ((0, 0), (0, rp_pad - r)))
    vp, rfp = pad_r(v), pad_r(refrac)
    if extra is None:
        extra = jnp.zeros((u, r), jnp.int32)
    xp = pad_r(extra.astype(jnp.int32))
    # padded neurons must never fire: give the pad lanes an unreachable
    # threshold by masking v to 0 (thresh >= 1 contract) — v pad is 0 and
    # syn pad is 0 (zero weights + zero extra), so fired_pad = (0 >= thresh)
    # = False.  (Fault pads are neutral-0: masked pad weight is
    # (0 & 0) ^ 0 = 0 and dth pad 0 keeps th_eff = thresh >= 1.)

    grid = (u, rp_pad // TILE_R)
    tile_spec = pl.BlockSpec((1, TILE_R), lambda i, j: (i, j))
    unit_spec = pl.BlockSpec((1,), lambda i, j: (i,))
    w_spec = pl.BlockSpec((1, c, TILE_R), lambda i, j: (i, 0, j))
    in_specs = [
        pl.BlockSpec((1, c), lambda i, j: (i, 0)),
        w_spec,
        tile_spec, tile_spec, tile_spec,
        unit_spec, unit_spec, unit_spec,
    ]
    operands = [spikes, wt, xp, vp, rfp, thresh, leak, refrac_period]
    kernel = _kernel
    if any(x is not None for x in (f_and, f_xor, dead, dth)):
        kernel = _kernel_faults
        fa = pad_w(jnp.full((u, r, c), -1, jnp.int8) if f_and is None
                   else f_and)
        fx = pad_w(jnp.zeros((u, r, c), jnp.int8) if f_xor is None else f_xor)
        dd = pad_r(jnp.zeros((u, r), jnp.int32) if dead is None
                   else dead.astype(jnp.int32))
        dt = pad_r(jnp.zeros((u, r), jnp.int32) if dth is None
                   else dth.astype(jnp.int32))
        in_specs = in_specs[:2] + [w_spec, w_spec] + in_specs[2:] + \
            [tile_spec, tile_spec]
        operands = operands[:2] + [fa, fx] + operands[2:] + [dd, dt]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[tile_spec, tile_spec, tile_spec],
        out_shape=[
            jax.ShapeDtypeStruct((u, rp_pad), jnp.int32),
            jax.ShapeDtypeStruct((u, rp_pad), jnp.int32),
            jax.ShapeDtypeStruct((u, rp_pad), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return out[0][:, :r], out[1][:, :r], out[2][:, :r]
