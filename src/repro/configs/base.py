"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig`` registered under its id and
selectable via ``--arch <id>`` in the launchers.  Shapes (train_4k /
prefill_32k / decode_32k / long_500k) are ``ShapeConfig`` entries; the
cross-product defines the dry-run / roofline cells.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    first_k_dense: int = 0  # leading dense layers (kimi-k2 style)
    d_ff_dense: int = 0  # d_ff of those dense layers


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    version: int  # 1 = Mamba (selective scan), 2 = Mamba2 (SSD)
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # mamba2 only
    dt_rank: int = 0  # mamba1 only; 0 -> d_model // 16
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0  # hybrid: shared attn block every k ssm layers
    n_enc_layers: int = 0  # encdec only
    mrope: bool = False  # vlm: multimodal 3D rope
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    n_vision_tokens: int = 0  # vlm: stub patch-embedding count
    n_audio_frames: int = 0  # encdec: default encoder length
    max_seq: int = 1_048_576
    params_dtype: Any = jnp.float32
    moments_dtype: Any = jnp.float32  # int8 for 8-bit Adam moments
    remat: str = "full"  # full | none
    attn_impl: str = "dense"  # dense | flash (train-path attention; §Perf hillclimb)
    fast_norm: bool = False  # normalize in bf16 (stats stay fp32); §Perf hillclimb
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without quadratic attention?"""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    accum_steps: int = 1  # gradient-accumulation microbatches (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", accum_steps=4),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "qwen3-1.7b",
    "stablelm-12b",
    "internlm2-1.8b",
    "granite-34b",
    "whisper-tiny",
    "kimi-k2-1t-a32b",
    "llama4-scout-17b-a16e",
    "falcon-mamba-7b",
    "qwen2-vl-72b",
    "zamba2-2.7b",
]

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

_REGISTRY: dict[str, ModelConfig] = {}
_SMOKE: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        importlib.import_module(_MODULE_FOR[arch])
    return _REGISTRY[arch]


def get_smoke_config(arch: str) -> ModelConfig:
    get_config(arch)
    return _SMOKE[arch]


def cells(arch: str) -> list[str]:
    """Runnable shape cells for an arch (long_500k only for sub-quadratic)."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # quadratic full attention at 524k: documented skip
        out.append(s.name)
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in cells(a)]


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        if not cfg.sub_quadratic:
            out.append((a, "long_500k", "pure full attention is quadratic at 524k ctx"))
    return out
