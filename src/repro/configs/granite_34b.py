"""granite-34b [dense] — 88L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576 vocab=49152.

llama-arch, code model.  [arXiv:2405.04324; hf-verified tier]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_head=128,
        d_ff=24576,
        vocab_size=49152,
        rope_theta=10_000.0,
        notes="MQA (kv=1) deep code model; kv heads replicated under TP",
    ),
    smoke=ModelConfig(
        name="granite-34b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=256,
        vocab_size=512,
    ),
)
