"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

Encoder-decoder; conv frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings.  [arXiv:2212.04356; unverified tier]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,  # decoder layers
        n_enc_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_head=64,
        d_ff=1536,
        vocab_size=51865,
        norm="layernorm",
        act="gelu",
        n_audio_frames=1500,
        tie_embeddings=True,
        notes="enc-dec audio backbone; frontend stubbed to frame embeddings; "
        "6 heads not divisible by TP=16 -> attention TP disabled (policy fallback)",
    ),
    smoke=ModelConfig(
        name="whisper-tiny-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        norm="layernorm",
        act="gelu",
        n_audio_frames=64,
        tie_embeddings=True,
    ),
)
