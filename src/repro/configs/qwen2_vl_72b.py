"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE + dynamic resolution; vision frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings.  [arXiv:2409.12191; hf-verified tier]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=29568,
        vocab_size=152064,
        mrope=True,
        mrope_sections=(16, 24, 24),
        n_vision_tokens=1024,
        rope_theta=1_000_000.0,
        notes="text backbone w/ M-RoPE; patch embeds merged at leading positions",
    ),
    smoke=ModelConfig(
        name="qwen2-vl-72b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        mrope=True,
        mrope_sections=(2, 3, 3),
        n_vision_tokens=8,
    ),
)
