from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    all_cells,
    cells,
    get_config,
    get_smoke_config,
    skipped_cells,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "all_cells",
    "cells",
    "get_config",
    "get_smoke_config",
    "skipped_cells",
]
