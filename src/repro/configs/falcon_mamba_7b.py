"""falcon-mamba-7b [ssm] — 64L d_model=4096 attn-free vocab=65024 ssm_state=16.

Mamba-1 architecture (selective scan).  [arXiv:2410.05355; unverified tier]
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_head=1,
        d_ff=0,
        vocab_size=65024,
        ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, dt_rank=256),
        tie_embeddings=True,
        notes="attention-free; O(1)-state decode -> long_500k runs; "
        "paper's crossbar offload applies to in/out projections only",
    ),
    smoke=ModelConfig(
        name="falcon-mamba-7b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_head=1,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(version=1, d_state=8, d_conv=4, expand=2, dt_rank=8, chunk=16),
        tie_embeddings=True,
    ),
)
