"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.

[hf:stabilityai/stablelm-2-1_6b family; hf-verified tier]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=160,
        d_ff=13824,
        vocab_size=100352,
        norm="layernorm",
        rope_theta=10_000.0,
        notes="parallel-residual-family dense decoder (LayerNorm)",
    ),
    smoke=ModelConfig(
        name="stablelm-12b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=160,
        vocab_size=512,
        norm="layernorm",
    ),
)
