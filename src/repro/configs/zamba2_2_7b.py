"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000 ssm_state=64.

Mamba-2 backbone + shared attention block every 6 layers (9 applications of a
single shared weight set).  [arXiv:2411.15242; hf-verified tier]
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_head=80,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, head_dim=64),
        attn_every=6,
        notes="hybrid: 9 groups of (shared attn block + 6 mamba2 layers); "
        "long_500k decode uses split-KV attention over the data axis",
    ),
    smoke=ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        ssm=SSMConfig(version=2, d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        attn_every=2,
    ),
)
