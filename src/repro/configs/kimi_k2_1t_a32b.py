"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert)
vocab=163840, MoE 384 experts top-8.

Trillion-param MoE (paper-table).  [arXiv:2501.kimi2; unverified tier]
Memory note: ~1.04e12 params.  bf16 params + int8 Adam moments + ZeRO-1 give
~4 bytes/param state -> 4.2 TB global; fits 512 chips (8.2 GB/chip) with
FSDP-style expert-weight sharding over the data axis; single-pod 256 chips is
borderline (16.4 GB/chip before activations) — recorded in EXPERIMENTS §Dry-run.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_head=112,
        d_ff=2048,  # expert FFN width
        vocab_size=163840,
        moe=MoEConfig(
            n_experts=384,
            top_k=8,
            d_ff_expert=2048,
            n_shared=1,
            capacity_factor=1.25,
            first_k_dense=1,
            d_ff_dense=18432,
        ),
        rope_theta=50_000.0,
        params_dtype=jnp.bfloat16,
        moments_dtype=jnp.int8,
        notes="1T-param MoE; EP over model axis (24 experts/shard), "
        "expert weights additionally FSDP-sharded over data axis",
    ),
    smoke=ModelConfig(
        name="kimi-k2-1t-a32b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=64,
        vocab_size=512,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_ff_expert=64,
            n_shared=1,
            first_k_dense=1,
            d_ff_dense=128,
        ),
    ),
)
