"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (+1 shared), early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified tier]
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=202048,
        moe=MoEConfig(
            n_experts=16,
            top_k=1,
            d_ff_expert=8192,
            n_shared=1,
            capacity_factor=1.25,
        ),
        rope_theta=500_000.0,
        notes="top-1 Switch-style routing + always-on shared expert (llama4)",
    ),
    smoke=ModelConfig(
        name="llama4-scout-17b-a16e-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, n_shared=1),
    ),
)
