"""Checkpointing: atomic, resumable, elastic, async — pure numpy/npz format
(no orbax dependency).

Layout: ``<dir>/step_<N>/`` containing ``shard_<i>.npz`` (flat leaf arrays)
+ ``manifest.json`` (tree structure, shapes, dtypes, checksum, step).  Writes
go to ``step_<N>.tmp`` and are renamed only after the manifest is fsync'd —
a crash mid-write never corrupts the latest checkpoint (restore picks the
newest *valid* manifest, which is how the failure-injection test recovers).

Elasticity: arrays are stored as full logical tensors (gathered), so a
restore may use a different mesh/dp-degree than the save — resharding is
just the in_shardings of the next jit call.  On a multi-host deployment each
host writes its addressable shards and the manifest records the index map
(single-process here, documented).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree, async_write: bool = False):
    """Atomic checkpoint write. Returns the final path (or a Thread)."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]  # device -> host copy now

    def _write():
        ckpt_dir.mkdir(parents=True, exist_ok=True)
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "shard_0.npz", **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        digest = hashlib.sha256()
        with open(tmp / "shard_0.npz", "rb") as f:
            for block in iter(lambda: f.read(1 << 20), b""):
                digest.update(block)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "sha256": digest.hexdigest(),
        }
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return final


def _valid(path: Path) -> bool:
    m = path / "manifest.json"
    s = path / "shard_0.npz"
    if not (m.exists() and s.exists()):
        return False
    try:
        manifest = json.loads(m.read_text())
        digest = hashlib.sha256()
        with open(s, "rb") as f:
            for block in iter(lambda: f.read(1 << 20), b""):
                digest.update(block)
        return digest.hexdigest() == manifest["sha256"]
    except Exception:
        return False


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        (int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*") if not p.name.endswith(".tmp")),
        reverse=True,
    )
    for s in steps:
        if _valid(ckpt_dir / f"step_{s:08d}"):
            return s
    return None


def restore(ckpt_dir, like_tree, step: int | None = None, shardings=None):
    """Restore into the structure of ``like_tree`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching NamedSharding tree
    for direct sharded device placement (elastic re-mesh)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    if not _valid(path):
        raise IOError(f"checkpoint {path} failed checksum validation")
    data = np.load(path / "shard_0.npz")
    leaves, treedef = _flatten(like_tree)
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    if shardings is not None:
        s_leaves = jax.tree.leaves(shardings)
        new_leaves = [jax.device_put(a, s) for a, s in zip(new_leaves, s_leaves)]
    return jax.tree.unflatten(treedef, new_leaves), step


def corrupt_for_test(ckpt_dir, step: int):
    """Failure injection: truncate a checkpoint's data file (tests only)."""
    p = Path(ckpt_dir) / f"step_{step:08d}" / "shard_0.npz"
    with open(p, "r+b") as f:
        f.truncate(max(p.stat().st_size // 2, 1))
