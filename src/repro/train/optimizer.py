"""AdamW optimizer, pure-JAX: ZeRO-1 sharded states, optional int8 moments.

ZeRO-1: moment tensors get an *extra* sharding over the ``data`` axis on the
largest axis the param spec leaves unsharded — optimizer state per chip drops
by the dp degree, params stay where TP put them.

int8 moments (``moments_dtype=int8``, used by kimi-k2's 1T params): blockwise
symmetric quantization along the last axis (fp32 scale per row), dequantized
transiently inside the update — 8-bit Adam with the classic 4 bytes/param
(bf16 param + 2×int8 moments) footprint instead of 12.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common import ParamSpec, is_spec


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moments_dtype: Any = jnp.float32


def schedule(oc: OptConfig, step):
    """Linear warmup -> cosine decay."""
    warm = jnp.minimum(step / max(oc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - oc.warmup_steps) / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    return oc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def zero1_pspec(spec: ParamSpec) -> P:
    """Add 'data' sharding on the largest axis the param pspec leaves free
    (skipped when the pspec already uses the data axis, e.g. FSDP weights)."""
    entries = list(spec.pspec) + [None] * (len(spec.shape) - len(spec.pspec))
    used = {a for e in entries if e is not None for a in (e if isinstance(e, tuple) else (e,))}
    if "data" in used:
        return P(*entries)
    best, best_size = None, 0
    for i, (e, n) in enumerate(zip(entries, spec.shape)):
        if e is None and n % 16 == 0 and n > best_size:
            best, best_size = i, n
    if best is None:
        return P(*entries)
    entries[best] = "data"
    return P(*entries)


def _moment_specs(pspec_tree, oc: OptConfig):
    def one(s: ParamSpec):
        zp = zero1_pspec(s)
        if oc.moments_dtype == jnp.int8:
            return {
                "q": ParamSpec(s.shape, jnp.int8, zp, init="zeros"),
                "scale": ParamSpec(s.shape[:-1], jnp.float32, P(*zp[:-1]), init="zeros"),
            }
        return ParamSpec(s.shape, jnp.float32, zp, init="zeros")

    return jax.tree.map(one, pspec_tree, is_leaf=is_spec)


def opt_specs(param_specs, oc: OptConfig):
    return {
        "m": _moment_specs(param_specs, oc),
        "v": _moment_specs(param_specs, oc),
        "step": ParamSpec((), jnp.int32, P(), init="zeros"),
    }


def _is_moment(x):
    return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}


def _dequant(mom):
    if _is_moment(mom):
        return mom["q"].astype(jnp.float32) * mom["scale"][..., None]
    return mom


def _requant(val, like):
    if _is_moment(like):
        scale = jnp.max(jnp.abs(val), axis=-1) / 127.0 + 1e-12
        q = jnp.round(val / scale[..., None]).astype(jnp.int8)
        return {"q": q, "scale": scale}
    return val


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(oc: OptConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(oc, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequant(m)
        v_f = _dequant(v)
        m_new = oc.b1 * m_f + (1 - oc.b1) * g
        v_new = oc.b2 * v_f + (1 - oc.b2) * g * g
        mhat = m_new / (1 - oc.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - oc.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, _requant(m_new, m), _requant(v_new, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=_is_moment)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=_is_moment)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    mdef = jax.tree.structure(opt_state["m"], is_leaf=_is_moment)
    new_m = jax.tree.unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(mdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm,
        "lr": lr,
    }
