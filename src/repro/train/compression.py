"""Gradient compression for the cross-pod (DCN) reduction: int8 blockwise
quantization with error feedback.

The slow link at 1000+-node scale is the pod-to-pod DCN; compressing the
outer-sync deltas 4× (fp32 -> int8 + fp32 scale per 256-block) with local
error-feedback accumulators preserves convergence (Seide et al.; 1-bit Adam
lineage).  Used by train/decoupled.py's outer sync and available for the
per-step DP all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress(x):
    """fp32 array -> (int8 q, fp32 scales, original shape)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    q = jnp.round(blocks / scale[:, None]).astype(jnp.int8)
    return q, scale, x.shape


def decompress(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_tree(tree, error_feedback=None):
    """Returns (compressed tree, new error feedback tree).

    error_feedback: residuals added before quantization and recomputed from
    the quantization error — the standard EF-SGD trick.
    """
    if error_feedback is None:
        error_feedback = jax.tree.map(jnp.zeros_like, tree)

    def one(x, e):
        xe = x + e
        q, s, shp = compress(xe)
        back = decompress(q, s, shp)
        return (q, s, shp), xe - back

    flat_x, tdef = jax.tree.flatten(tree)
    flat_e = jax.tree.leaves(error_feedback)
    outs = [one(x, e) for x, e in zip(flat_x, flat_e)]
    comp = jax.tree.unflatten(tdef, [o[0] for o in outs])
    ef = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return comp, ef


def decompress_tree(comp):
    return jax.tree.map(
        lambda c: decompress(*c), comp, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
    )


def compressed_bytes(tree) -> int:
    return sum(
        x.size + 4 * (x.size // BLOCK + 1)
        for x in jax.tree.leaves(tree)
    )
