"""Time-decoupled data parallelism across pods — the paper's technique
lifted from simulation to training (DESIGN.md §2, beyond-paper feature).

The paper lets simulation segments run ``quantum`` units ahead of each other
bounded by channel latency before a synchronization.  Applied to multi-pod
training: each pod runs ``quantum`` *local* optimizer steps (inner loop, no
cross-pod collectives — DCN stays idle), then an outer synchronization
averages the pods' parameter deltas with outer momentum (DiLoCo-style).  The
quantum bounds the parameter staleness exactly as the channel latency bounds
simulated-time skew; a transiently slow pod only delays the (rare) outer
sync — straggler mitigation at pod granularity.

Pure-functional API mirroring train_step: state carries the inner state per
pod plus the outer params/momentum.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common import is_spec
from repro.train.optimizer import OptConfig


@dataclasses.dataclass(frozen=True)
class DecoupledConfig:
    quantum: int = 8  # inner steps per outer sync (the paper's N)
    outer_lr: float = 0.7
    outer_momentum: float = 0.9


def outer_state_specs(model):
    """Outer momentum buffer matches the param tree."""
    import dataclasses as dc

    from repro.common import ParamSpec

    return jax.tree.map(
        lambda s: dc.replace(s, init="zeros", dtype=jnp.float32),
        model.specs,
        is_leaf=is_spec,
    )


def make_decoupled_round(model, oc: OptConfig, dc_cfg: DecoupledConfig,
                         inner_step, n_pods: int):
    """Returns round(inner_states, outer, batches) -> (inner_states, outer, metrics).

    inner_states: pytree stacked over the pod axis (leading dim n_pods);
    batches: leaves (n_pods, quantum, per-pod-batch...).  The inner loop is
    a lax.scan per pod (vmapped over pods — on a multi-pod deployment this
    axis maps onto the DCN-disjoint pods and the vmap becomes shard_map over
    'pod'); the outer sync is the only cross-pod communication.
    """

    def pod_quantum(state, batches):
        def body(st, b):
            st, metrics = inner_step(st, b)
            return st, metrics["loss"]

        state, losses = jax.lax.scan(body, state, batches)
        return state, losses.mean()

    def outer_sync(outer, inner_states):
        params0 = outer["params"]
        # average pod deltas (all-reduce over 'pod' on a real deployment)
        delta = jax.tree.map(
            lambda p0, ps: (ps.astype(jnp.float32) - p0.astype(jnp.float32)).mean(0),
            params0,
            inner_states["params"],
        )
        mom = jax.tree.map(
            lambda m, d: dc_cfg.outer_momentum * m + d, outer["momentum"], delta
        )
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) + dc_cfg.outer_lr * m).astype(p.dtype),
            params0,
            mom,
        )
        return {"params": new_params, "momentum": mom}

    def round(inner_states, outer, batches):
        inner_states, losses = jax.vmap(pod_quantum)(inner_states, batches)
        outer = outer_sync(outer, inner_states)
        # re-seed every pod's params from the synced outer params
        bcast = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (n_pods,) + p.shape), outer["params"]
        )
        inner_states = {**inner_states, "params": bcast}
        return inner_states, outer, {"loss": losses.mean(), "pod_losses": losses}

    return round
