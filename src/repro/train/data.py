"""Deterministic synthetic token pipeline.

Generates a structured integer-sequence language (nested arithmetic-like
patterns with copy/repeat structure) so the loss curve actually *decreases*
during the example training runs — pure-noise tokens would pin CE at
log(V).  Sharding: each (pod, data) shard draws only its slice of the batch
from a counter-based RNG keyed on (seed, step, shard) — no host broadcast,
restart-stable, and identical regardless of dp degree (elastic-safe).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 16  # markov-ish period; smaller = easier


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Host-side batch (tests/examples). Deterministic in (cfg, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # small active alphabet (unigram structure learnable within a few steps)
    # + periodic copy structure x[t] = f(x[t-period]) (in-context structure)
    alpha = min(v, 64)
    period = cfg.structure
    base = rng.integers(0, alpha, (b, period))
    reps = -(-s // period)
    toks = np.tile(base, (1, reps))[:, :s]
    drift = rng.integers(0, alpha, (b, s))
    mask = rng.random((b, s)) < 0.1
    toks = np.where(mask, drift, (toks + np.arange(s) // period) % alpha)
    return {"tokens": jnp.asarray(toks, jnp.int32)}


def device_batch_at(cfg: DataConfig, step: int, mesh=None, extras=None) -> dict:
    """Batch placed with the training in_shardings (batch over data axes)."""
    batch = batch_at(cfg, step)
    if extras:
        batch.update(extras(cfg, step))
    if mesh is not None:
        from jax.sharding import PartitionSpec as P

        from repro.common import named

        batch = {
            k: jax.device_put(v, named(mesh, P(("data", "pod"), *([None] * (v.ndim - 1)))))
            for k, v in batch.items()
        }
    return batch
