"""Train step: microbatched gradient accumulation, sharded accumulators,
grad clip, AdamW — all pure functions of (state, batch).

Scale-out details:
- gradient accumulation runs as a ``lax.scan`` over microbatches; after each
  microbatch the gradient is *constrained to the ZeRO-1 sharding* so the
  accumulator lives reduce-scattered across the ``data`` axis (per-chip grad
  memory = params/dp, the ZeRO-2 trick) instead of replicated.
- optimizer state is ZeRO-1 sharded (see optimizer.py); param updates gather
  transparently through GSPMD.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import ParamSpec, is_spec, shape_dtypes, spec_map
from repro.train.optimizer import OptConfig, adamw_update, opt_specs, zero1_pspec


def state_specs(model, oc: OptConfig):
    """Spec tree for the full train state {params, opt}."""
    return {"params": model.specs, "opt": opt_specs(model.specs, oc)}


def grad_pspecs(model):
    return jax.tree.map(lambda s: zero1_pspec(s), model.specs, is_leaf=is_spec)


def make_train_step(model, oc: OptConfig, accum_steps: int = 1, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have a leading global-batch axis; with accum_steps > 1 they
    are split into microbatches scanned sequentially.
    """
    gspecs = grad_pspecs(model)
    pspecs = jax.tree.map(lambda s: s.pspec, model.specs, is_leaf=is_spec)

    def _constrain(g, specs):
        if mesh is None:
            return g
        from repro.common import with_sharding

        return jax.tree.map(lambda x, s: with_sharding(x, mesh, s), g, specs)

    def constrain_grads(g):
        return _constrain(g, gspecs)

    def barrier_grads(g):
        """Pin fresh grads to their params' own sharding.

        Without this, the ZeRO-1 accumulator sharding (e.g. router grads
        sharded d-over-data) propagates backwards INTO the MoE shard_map
        region and triggers an SPMD involuntary full rematerialization of the
        activations; the explicit constraint makes the (tiny) reshard happen
        on the gradient itself at the accumulate boundary instead.
        """
        return _constrain(g, pspecs)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, mesh=mesh)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]

        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = constrain_grads(barrier_grads(grads))
        else:
            # batch axis is 0 for all inputs except mrope_pos (3, B, S)
            def split(path, x):
                ax = 1 if any(getattr(p, "key", None) == "mrope_pos" for p in path) else 0
                b = x.shape[ax]
                shp = x.shape[:ax] + (accum_steps, b // accum_steps) + x.shape[ax + 1 :]
                return jnp.moveaxis(x.reshape(shp), ax, 0)

            mbs = jax.tree_util.tree_map_with_path(split, batch)
            zero_g = jax.tree.map(
                lambda s: jnp.zeros(s.shape, jnp.float32), model.specs, is_leaf=is_spec
            )
            zero_g = constrain_grads(zero_g)

            def mb_step(carry, mb):
                gsum, lsum = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g = barrier_grads(g)
                gsum = constrain_grads(
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                )
                return (gsum, lsum + l), metrics

            (grads, loss_sum), metrics = jax.lax.scan(
                mb_step, (zero_g, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = jax.tree.map(lambda x: x.mean(), metrics)

        new_params, new_opt, opt_metrics = adamw_update(oc, params, grads, state["opt"])
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step
