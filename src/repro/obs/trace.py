"""Device-resident trace event rings: the VP's telemetry capture layer.

A *trace ring* is a fixed-capacity structure-of-arrays buffer of int32
``(kind, seg, unit, t, value)`` records that rides INSIDE the simulation
state pytree — one ring per segment, stacked like everything else — so
traced code (the per-quantum segment step, under jit/vmap/shard_map and
inside the controller's device-resident megaloop) can append events without
any host round-trip.  The host drains rings only at dispatch boundaries,
piggybacking on the controller's existing one-scalar-tuple sync
(core/controller.py ``run``), which preserves the megaloop's
one-device-sync-per-dispatch contract with telemetry enabled.

Appends past capacity are *dropped, never blocking*: ``count`` keeps
recording true demand, and the sticky ``overflowed`` flag joins
``platform.termination_flags`` as flag 6 — purely informational (the
controller reports lost events via ``trace_lost``; it never raises), unlike
the channel watermarks, because losing telemetry must never change or stop
a simulation.

Event kinds (see docs/observability.md for the full schema):

  ==============  ===============================  =====================
  kind            unit field                       value field
  ==============  ===============================  =====================
  EV_QUANTUM      instructions this quantum        local-time advance
  EV_ROUTE        inbox occupancy before consume   messages consumed
  EV_TICK         CIM slot                         neurons fired
  EV_SPIKE_TX     CIM slot (source)                dst_seg << 16 | spikes
  EV_CIM_START    CIM slot                         busy_until (end time)
  EV_CIM_DONE     CIM slot                         output rows DMA'd
  EV_WMARK        -1                               watermark id (0..3)
  EV_FAULT        spikes duplicated this round     spikes dropped in flight
  EV_SPIKE_LOSS   -1                               spikes lost to overflow
  ==============  ===============================  =====================

``t`` is always the *simulated* time (cycles) the event belongs to —
quantum start, LIF tick grid time, OP completion time — never host time,
so traces are bit-identical across backends and dispatch modes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

EV_QUANTUM = 0   # one per segment per round in which local time advanced
EV_ROUTE = 1     # inbox messages consumed at the round's inbox application
EV_TICK = 2      # a spike-mode unit fired its LIF tick
EV_SPIKE_TX = 3  # AER spikes emitted toward one fan-out destination
EV_CIM_START = 4  # a dense CIM OP launched (MMIO CIM_REG_START applied)
EV_CIM_DONE = 5  # a dense CIM OP completed + DMA'd its output rows
EV_WMARK = 6     # a sticky watermark tripped (first time only, per segment)
EV_FAULT = 7     # seeded transport faults fired (drop/duplication, faults/)
EV_SPIKE_LOSS = 8  # graceful degradation: spikes lost to outbox overflow

KIND_NAMES = ("quantum", "route", "tick", "spike_tx", "cim_start",
              "cim_done", "watermark", "fault_injected", "spikes_dropped")
WMARK_NAMES = ("inbox", "outbox", "store_log", "snn_mmio_late")

FIELDS = ("kind", "seg", "unit", "t", "value")
EVENT_DTYPE = np.dtype([(f, np.int32) for f in FIELDS])


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Static telemetry configuration (hashable: it keys the controller's
    compiled-function cache — tracing is compiled *in* when present and
    compiled *out* entirely when ``Controller(obs=None)``).

    capacity: ring slots per segment.  Size it for the drain cadence: the
    fused megaloop drains once per dispatch, so the ring must hold every
    event of up to ``rounds_per_dispatch`` rounds (per-round dispatch and
    the host-loop backends drain at every ``check_every`` boundary, which
    needs far less).  Undersizing drops events and sets the sticky
    overflow flag — it never blocks and never perturbs the simulation.
    """
    capacity: int = 4096


def ring_state(cap: int):
    """One segment's empty ring (stack n of them like the platform state)."""
    ring = {f: jnp.zeros((cap,), jnp.int32) for f in FIELDS}
    ring["count"] = jnp.zeros((), jnp.int32)        # demand, may exceed cap
    ring["overflowed"] = jnp.zeros((), jnp.bool_)   # sticky: events were lost
    ring["wmark_seen"] = jnp.zeros((), jnp.int32)   # EV_WMARK dedup bitmask
    return ring


def emit(ring, mask, kind, seg, unit, t, value):
    """Append one record (if ``mask``) at the current count.

    Past-capacity appends drop (scatter out-of-bounds, channel.py's "never
    write a dead slot" rule); ``count`` still increments so the drain can
    report how many events were lost."""
    cap = ring["kind"].shape[0]
    mask = jnp.asarray(mask)
    i = jnp.where(mask & (ring["count"] < cap), ring["count"], cap)
    out = dict(ring)
    for f, v in (("kind", kind), ("seg", seg), ("unit", unit), ("t", t),
                 ("value", value)):
        out[f] = ring[f].at[i].set(jnp.asarray(v, jnp.int32), mode="drop")
    out["count"] = ring["count"] + mask.astype(jnp.int32)
    out["overflowed"] = ring["overflowed"] | (out["count"] > cap)
    return out


def emit_bulk(ring, mask, kind, seg, unit, t, value):
    """Append a vector of records (``mask`` selects lanes) preserving lane
    order.  Deliberately scatter-based, NOT the gather formulation of
    channel.box_append_bulk: a gather/where pass is O(ring capacity) *per
    emission site*, which dominates the dispatch-bound megaloop regime,
    while a lane-serial scatter of a handful of records is O(lanes) and
    updates the donated ring in place (the telemetry-overhead benchmark
    line guards this).  Past-capacity records drop via out-of-bounds
    indices (``mode="drop"``); ``count`` records true demand."""
    cap = ring["kind"].shape[0]
    n = mask.shape[0]
    mask = mask.astype(jnp.int32)
    offs = jnp.cumsum(mask) - mask  # rank of each selected lane, lane order
    i = jnp.where(mask.astype(bool), ring["count"] + offs, cap)
    out = dict(ring)
    for f, v in (("kind", kind), ("seg", seg), ("unit", unit), ("t", t),
                 ("value", value)):
        vals = jnp.broadcast_to(jnp.asarray(v, jnp.int32), (n,))
        out[f] = ring[f].at[i].set(vals, mode="drop")
    out["count"] = ring["count"] + mask.sum()
    out["overflowed"] = ring["overflowed"] | (out["count"] > cap)
    return out


def reset(ring):
    """Ring after a host drain: count rewinds to zero, the sticky
    ``overflowed`` flag and the EV_WMARK dedup mask are preserved (they
    are cross-drain semantics, not buffer contents)."""
    out = dict(ring)
    out["count"] = jnp.zeros_like(ring["count"])
    return out


def drain(host_ring):
    """Host-side drain of a stacked ``(S, ...)`` ring already fetched from
    the device (plain numpy in, so this never adds a device sync).

    Returns ``(events, lost)``: a chronologically sorted structured array
    of ``EVENT_DTYPE`` records and the number of records dropped to
    capacity since the previous drain."""
    counts = np.asarray(host_ring["count"])
    cap = np.asarray(host_ring["kind"]).shape[1]
    parts, lost = [], 0
    for s in range(counts.shape[0]):
        n = int(counts[s])
        lost += max(0, n - cap)
        n = min(n, cap)
        e = np.empty(n, EVENT_DTYPE)
        for f in FIELDS:
            e[f] = np.asarray(host_ring[f])[s, :n]
        parts.append(e)
    events = np.concatenate(parts) if parts else np.empty(0, EVENT_DTYPE)
    return events[np.argsort(events["t"], kind="stable")], lost
