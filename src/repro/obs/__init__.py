"""Observability subsystem: device-resident telemetry for the VP.

Module map:
  trace.py   — fixed-capacity trace event rings carried inside the megaloop
               state; appended in traced code, drained at dispatch
               boundaries (never an extra host sync), sticky overflow as
               termination flag 6 (informational, never blocking)
  metrics.py — typed metrics registry (counters/gauges/histograms) over the
               simulation state; the back-compat source of
               ``Controller.stats()``
  export.py  — Chrome-trace/Perfetto JSON timeline export (per-segment /
               per-CIM-unit tracks, cross-segment spike flow arrows) and
               the NDJSON streaming format behind
               ``Controller.run(..., on_telemetry=...)``

Everything here is opt-in: ``Controller(obs=None)`` (the default) compiles
all tracing out, leaving the hot path untouched; ``obs=TraceConfig(...)``
turns it on with bit-identical simulation results (tests/test_obs.py,
tests/test_conformance.py).  See docs/observability.md.
"""
from repro.obs.trace import EVENT_DTYPE, KIND_NAMES, TraceConfig

__all__ = ["EVENT_DTYPE", "KIND_NAMES", "TraceConfig"]
