"""Typed metrics registry over the simulation state.

Every quantity the VP already tracks on-device — per-segment stats
counters, per-unit CIM/SNN counters, channel watermarks — is declared here
once as a typed ``Metric`` (counter / gauge / histogram + unit + axis), so
tools iterate the registry instead of hard-coding state paths, and new
counters get discoverable names + docs for free.

``collect(states, pending)`` snapshots the registry from stacked state (a
pure host-side read: the caller provides already-stacked pytrees, e.g.
``Controller.metrics()``).  ``legacy_stats(states)`` reproduces the exact
historical ``Controller.stats()`` dict — the back-compat shim contract is
pinned by tests/test_obs.py.

Kinds:
  counter   — monotonically nondecreasing over a run (events, ops, spikes)
  gauge     — instantaneous or high-water level (occupancy, watermarks)
  histogram — binned counts (the Fig. 1a transaction-kind histogram)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class Metric:
    name: str
    kind: str  # "counter" | "gauge" | "histogram"
    unit: str
    per: str  # "segment" | "unit" | "bin"
    description: str
    source: str  # "states" | "pending"
    extract: Callable = dataclasses.field(compare=False, repr=False)


REGISTRY: dict[str, Metric] = {}


def _register(name, kind, unit, per, description, source="states"):
    def deco(fn):
        REGISTRY[name] = Metric(name, kind, unit, per, description, source, fn)
        return fn

    return deco


_A = lambda x: np.asarray(x)

_register("cpu.instructions", "counter", "instructions", "segment",
          "RISC-V instructions retired per segment CPU")(
    lambda s: _A(s["stats"]["instrs"]))
_register("channel.messages_emitted", "counter", "messages", "segment",
          "TLM messages appended to each segment's outbox")(
    lambda s: _A(s["stats"]["msgs"]))
_register("channel.txn_histogram", "histogram", "messages", "bin",
          "consumed inbox messages binned by kind (Fig. 1a; bins are "
          "channel.MSG_* ids, per segment)")(
    lambda s: _A(s["stats"]["txn_hist"]))
_register("channel.outbox_watermark", "gauge", "messages", "segment",
          "sticky per-round outbox high-water mark (vs VPConfig.out_cap)")(
    lambda s: _A(s["stats"]["outbox_peak"]))
_register("channel.inbox_watermark", "gauge", "messages", "segment",
          "sticky inbox merge high-water mark (vs VPConfig.in_cap)",
          source="pending")(
    lambda p: _A(p["max_count"]))
_register("channel.inbox_occupancy", "gauge", "messages", "segment",
          "valid messages currently pending per segment inbox",
          source="pending")(
    lambda p: _A(p["valid"]).sum(-1))
_register("channel.messages_routed", "counter", "messages", "segment",
          "messages ever routed toward each segment (route demand, "
          "counted even when a merge truncates)", source="pending")(
    lambda p: _A(p["routed_total"]))
_register("mem.dcache_hits", "counter", "accesses", "segment",
          "D-cache hits")(lambda s: _A(s["dcache"]["hits"]))
_register("mem.dcache_misses", "counter", "accesses", "segment",
          "D-cache misses")(lambda s: _A(s["dcache"]["misses"]))
_register("mem.dram_reads", "counter", "accesses", "segment",
          "DRAM read accesses")(lambda s: _A(s["dram"]["reads"]))
_register("mem.dram_writes", "counter", "accesses", "segment",
          "DRAM writes (local stores + posted remote writes)")(
    lambda s: _A(s["dram"]["writes"]))
_register("mem.store_log_watermark", "gauge", "stores", "segment",
          "sticky per-quantum DRAM store-log high-water mark (vs "
          "VPConfig.store_log)")(
    lambda s: _A(s["stats"]["store_peak"]))
_register("cim.dense_ops", "counter", "ops", "unit",
          "dense VMM OPs completed per CIM unit")(
    lambda s: _A(s["cims"]["ops"]))
_register("snn.ticks", "counter", "ticks", "unit",
          "LIF ticks fired per spike-mode unit")(
    lambda s: _A(s["cims"]["ticks"]))
_register("snn.spikes_emitted", "counter", "spikes", "unit",
          "spikes emitted per spike-mode unit (stripe owner counters)")(
    lambda s: _A(s["cims"]["spikes_total"]))
_register("snn.spikes_in", "counter", "spikes", "unit",
          "AER spike events integrated per unit (consumed-side traffic; "
          "snn.consumed_rates aggregates this per stripe group)")(
    lambda s: _A(s["cims"]["spikes_in"]))
_register("snn.spikes_consumed", "counter", "spikes", "segment",
          "AER spike events integrated per segment")(
    lambda s: _A(s["stats"]["spikes_consumed"]))
_register("snn.mmio_late", "counter", "ops", "segment",
          "hybrid MMIO ops that violated their tick-grid deadline "
          "(sticky; nonzero raises in the controller)")(
    lambda s: _A(s["stats"]["snn_mmio_late"]))
_register("channel.inbox_lost", "counter", "messages", "segment",
          "messages discarded by truncating inbox merges (nonzero only "
          "under faults.FaultConfig(on_overflow='drop'); otherwise the "
          "inbox watermark aborts first)", source="pending")(
    lambda p: _A(p["lost_total"]))


# fault-injection counters (repro.faults): the stats keys exist only when
# the platform was built with the matching fault family enabled — the
# extractors report zeros otherwise, so collect() stays total
def _stat_or_zeros(s, key):
    st = s["stats"]
    return _A(st[key]) if key in st else np.zeros_like(_A(st["instrs"]))


_register("faults.spikes_dropped", "counter", "spikes", "segment",
          "AER spikes lost in flight to seeded transport faults "
          "(faults.FaultConfig.p_spike_drop)")(
    lambda s: _stat_or_zeros(s, "spikes_dropped"))
_register("faults.spikes_duped", "counter", "spikes", "segment",
          "AER spikes delivered twice by seeded transport faults "
          "(faults.FaultConfig.p_spike_dup)")(
    lambda s: _stat_or_zeros(s, "spikes_duped"))
_register("faults.outbox_lost", "counter", "spikes", "segment",
          "messages truncated at the outbox under the graceful-degradation "
          "overflow policy (faults.FaultConfig(on_overflow='drop'))")(
    lambda s: _stat_or_zeros(s, "outbox_lost"))


def collect(states, pending=None) -> dict:
    """Snapshot every registered metric from stacked state.

    Returns ``{name: ndarray}`` — counters/gauges are ``(S,)`` or
    ``(S, n_units)``, the histogram ``(S, 8)``.  ``pending``-sourced
    metrics (channel occupancy/watermark/routed) are skipped when no
    pending box is supplied.
    """
    out = {}
    for m in REGISTRY.values():
        if m.source == "pending":
            if pending is None:
                continue
            out[m.name] = m.extract(pending)
        else:
            out[m.name] = m.extract(states)
    return out


def describe() -> list:
    """Registry rows (name, kind, unit, per, description) for docs/tools."""
    return [(m.name, m.kind, m.unit, m.per, m.description)
            for m in REGISTRY.values()]


def legacy_stats(states) -> dict:
    """The historical ``Controller.stats()`` dict, bit-for-bit.

    Kept as a thin view over the registry's sources so existing callers
    (benchmarks, examples, tests) keep working; new code should prefer
    ``Controller.metrics()`` / ``collect``.  The shape of this dict is a
    compatibility contract — tests/test_obs.py pins it.
    """
    st = states["stats"]
    return {
        "instructions": np.asarray(st["instrs"]),
        "messages": np.asarray(st["msgs"]),
        "txn_histogram": np.asarray(st["txn_hist"]).sum(0),
        "cache": {
            "d_hits": np.asarray(states["dcache"]["hits"]),
            "d_misses": np.asarray(states["dcache"]["misses"]),
        },
        "dram": {
            "reads": np.asarray(states["dram"]["reads"]),
            "writes": np.asarray(states["dram"]["writes"]),
        },
        "cim_ops": np.asarray(states["cims"]["ops"]),
        "snn": {
            "spikes": np.asarray(states["cims"]["spikes_total"]),
            "ticks": np.asarray(states["cims"]["ticks"]),
        },
    }
