"""Trace export: Chrome-trace/Perfetto JSON timelines + NDJSON streams.

``to_chrome_trace`` turns drained trace events (obs/trace.py records) into
the Chrome Trace Event JSON format, which both ``chrome://tracing`` and
https://ui.perfetto.dev load directly:

  - one *process* per segment, one *thread* per track: tid 0 is the
    segment/CPU track (quantum slices, inbox counters), tid 1+u is CIM
    unit u's track (dense OP slices, LIF tick instants);
  - cross-segment spike bursts become flow events (``ph: s``/``f``
    arrows) from the emitting unit's tick to the destination segment one
    tick later — the AER one-tick-per-hop delay drawn on screen;
  - simulated cycles map 1:1 onto trace microseconds (the formats have no
    cycle unit; all times in a trace are simulated, so only ratios
    matter).

``validate_chrome_trace`` checks the schema contract the CI smoke job
enforces on the exported artifact.  The NDJSON writers stream drained
batches as one flat JSON object per line — the
``Controller.run(..., on_telemetry=...)`` dashboard format
(docs/observability.md).
"""
from __future__ import annotations

import json

import numpy as np

from repro.obs import trace as tr


def _tracks(events):
    """(segments, units-per-segment) observed in an event batch."""
    segs = sorted(int(s) for s in np.unique(events["seg"]))
    units = {
        s: sorted(int(u) for u in np.unique(
            events["unit"][(events["seg"] == s)
                           & np.isin(events["kind"],
                                     (tr.EV_TICK, tr.EV_SPIKE_TX,
                                      tr.EV_CIM_START, tr.EV_CIM_DONE))]))
        for s in segs
    }
    return segs, units


def to_chrome_trace(events, tick_period: int = 0, title: str = "repro-vp"):
    """Chrome Trace Event JSON (dict) from drained trace records.

    ``tick_period`` (meta["tick_period"] for SNN builds) dates spike-flow
    arrival one LIF tick after emission; 0 draws zero-length flows.
    """
    te = []
    segs, units = _tracks(events)
    for s in segs:
        te.append({"name": "process_name", "ph": "M", "pid": s,
                   "args": {"name": f"segment {s}"}})
        te.append({"name": "thread_name", "ph": "M", "pid": s, "tid": 0,
                   "args": {"name": "cpu/segment"}})
        for u in units[s]:
            te.append({"name": "thread_name", "ph": "M", "pid": s,
                       "tid": 1 + u,
                       "args": {"name": f"cim unit {u}"}})
    flow_id = 0
    for r in events:
        kind, seg, unit = int(r["kind"]), int(r["seg"]), int(r["unit"])
        t, value = int(r["t"]), int(r["value"])
        if kind == tr.EV_QUANTUM:
            te.append({"name": "quantum", "ph": "X", "pid": seg, "tid": 0,
                       "ts": t, "dur": value,
                       "args": {"instructions": unit}})
        elif kind == tr.EV_ROUTE:
            te.append({"name": "inbox", "ph": "C", "pid": seg, "tid": 0,
                       "ts": t,
                       "args": {"consumed": value, "occupancy": unit}})
        elif kind == tr.EV_TICK:
            te.append({"name": "tick", "ph": "i", "pid": seg,
                       "tid": 1 + unit, "ts": t, "s": "t",
                       "args": {"fired": value}})
        elif kind == tr.EV_SPIKE_TX:
            dst_seg, n_spikes = value >> 16, value & 0xFFFF
            flow_id += 1
            te.append({"name": "spikes", "ph": "s", "id": flow_id,
                       "pid": seg, "tid": 1 + unit, "ts": t,
                       "args": {"spikes": n_spikes, "dst_seg": dst_seg}})
            te.append({"name": "spikes", "ph": "f", "bp": "e",
                       "id": flow_id, "pid": dst_seg, "tid": 0,
                       "ts": t + tick_period,
                       "args": {"spikes": n_spikes}})
        elif kind == tr.EV_CIM_START:
            te.append({"name": "cim_op", "ph": "X", "pid": seg,
                       "tid": 1 + unit, "ts": t, "dur": max(value - t, 0),
                       "args": {"busy_until": value}})
        elif kind == tr.EV_CIM_DONE:
            te.append({"name": "cim_done", "ph": "i", "pid": seg,
                       "tid": 1 + unit, "ts": t, "s": "t",
                       "args": {"rows": value}})
        elif kind == tr.EV_WMARK:
            wm = tr.WMARK_NAMES[value] if 0 <= value < len(tr.WMARK_NAMES) \
                else str(value)
            te.append({"name": f"watermark:{wm}", "ph": "i", "pid": seg,
                       "tid": 0, "ts": t, "s": "p", "args": {"flag": value}})
        elif kind == tr.EV_FAULT:
            te.append({"name": "fault_injected", "ph": "i", "pid": seg,
                       "tid": 0, "ts": t, "s": "p",
                       "args": {"dropped": value, "duplicated": unit}})
        elif kind == tr.EV_SPIKE_LOSS:
            te.append({"name": "spikes_dropped", "ph": "i", "pid": seg,
                       "tid": 0, "ts": t, "s": "p",
                       "args": {"lost": value}})
    return {
        "traceEvents": te,
        "displayTimeUnit": "ms",
        "otherData": {"title": title,
                      "timeUnit": "1 trace us = 1 simulated cycle"},
    }


_PHASES = {"X", "i", "C", "M", "s", "f"}


def validate_chrome_trace(obj) -> list:
    """Schema check for an exported trace; returns a list of problems
    (empty = valid).  This is the contract the CI telemetry smoke job
    enforces before uploading the artifact."""
    problems = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a traceEvents array"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list) or not evs:
        return ["traceEvents must be a non-empty array"]
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: bad ph {ph!r}")
            continue
        if "pid" not in e or "name" not in e:
            problems.append(f"{where}: missing pid/name")
        if ph != "M" and not isinstance(e.get("ts"), int):
            problems.append(f"{where}: {ph!r} event needs integer ts")
        if ph == "X" and (not isinstance(e.get("dur"), int) or e["dur"] < 0):
            problems.append(f"{where}: X slice needs dur >= 0")
        if ph in ("s", "f") and "id" not in e:
            problems.append(f"{where}: flow event needs an id")
        if ph == "M" and "args" not in e:
            problems.append(f"{where}: metadata event needs args")
    ids = {}
    for e in evs:
        if isinstance(e, dict) and e.get("ph") in ("s", "f"):
            ids.setdefault(e.get("id"), set()).add(e["ph"])
    for fid, phs in ids.items():
        if phs != {"s", "f"}:
            problems.append(f"flow id {fid} lacks a matched s/f pair")
    return problems


def write_chrome_trace(path, events, tick_period: int = 0,
                       title: str = "repro-vp"):
    """Export + validate + write; returns the trace dict."""
    obj = to_chrome_trace(events, tick_period=tick_period, title=title)
    problems = validate_chrome_trace(obj)
    assert not problems, f"invalid chrome trace: {problems[:5]}"
    with open(path, "w") as fh:
        json.dump(obj, fh)
    return obj


# ---------------------------------------------------------------------------
# NDJSON streaming (the on_telemetry dashboard format)


def ndjson_records(events):
    """Flat dicts, one per trace record, with the kind name spelled out."""
    for r in events:
        kind = int(r["kind"])
        yield {
            "kind": tr.KIND_NAMES[kind] if 0 <= kind < len(tr.KIND_NAMES)
            else str(kind),
            "seg": int(r["seg"]),
            "unit": int(r["unit"]),
            "t": int(r["t"]),
            "value": int(r["value"]),
        }


def write_ndjson(fh, events) -> int:
    """Append one JSON object per event to ``fh``; returns lines written."""
    n = 0
    for rec in ndjson_records(events):
        fh.write(json.dumps(rec) + "\n")
        n += 1
    return n


def ndjson_callback(fh):
    """An ``on_telemetry`` callback streaming every drained batch to ``fh``
    as NDJSON — ``Controller.run(..., on_telemetry=ndjson_callback(f))``."""
    def cb(events):
        write_ndjson(fh, events)
        fh.flush()

    return cb
