"""End-to-end training driver.

Runs any ``--arch`` (full or ``--smoke`` reduced config, optionally scaled
with --layers/--d-model) with AdamW, microbatching, checkpoints and
auto-resume.  On this CPU container the smoke configs train in seconds; the
full configs are exercised through the dry-run (launch/dryrun.py).

Fault tolerance demo: ``--fail-at-step N`` hard-exits mid-run; re-invoking
with the same --ckpt-dir resumes from the newest *valid* checkpoint (atomic
writes + checksums; see train/checkpoint.py).

XLA flags for a real TPU deployment (latency-hiding overlap of the DP
collectives with backward compute) are listed in README §Deployment:
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_overlap_compute_collective_tc=true
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batch_at
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, state_specs
from repro.common import init_params, shape_dtypes


def extras_for(cfg, batch, seq):
    out = {}
    if cfg.family == "vlm":
        out["vision_embeds"] = jnp.zeros((batch, min(cfg.n_vision_tokens, seq // 2), cfg.d_model), jnp.bfloat16)
        out["mrope_pos"] = jnp.tile(jnp.arange(seq, dtype=jnp.int32)[None, None], (3, batch, 1))
    if cfg.family == "encdec":
        out["enc_feats"] = jnp.zeros((batch, seq, cfg.d_model), jnp.bfloat16)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--layers", type=int, default=0, help="override depth")
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=0, help="failure injection")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    over = {}
    if args.layers:
        over["n_layers"] = args.layers
    if args.d_model:
        over["d_model"] = args.d_model
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = build(cfg, tp=1)
    oc = OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                   moments_dtype=cfg.moments_dtype)
    sspecs = state_specs(model, oc)
    from repro.common import param_count

    print(f"arch={cfg.name}  params={param_count(model.specs)/1e6:.1f}M  "
          f"steps={args.steps}  batch={args.batch}x{args.seq}")

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt.restore(args.ckpt_dir, shape_dtypes(sspecs))
        print(f"resumed from checkpoint step {start_step}")
    else:
        state = {"params": model.init(jax.random.PRNGKey(0)),
                 "opt": init_params(jax.random.PRNGKey(1), sspecs["opt"])}

    step_fn = jax.jit(make_train_step(model, oc, accum_steps=args.accum), donate_argnums=(0,))
    dc = DataConfig(cfg.vocab_size, args.seq, args.batch, seed=7)
    ex = extras_for(cfg, args.batch, args.seq)
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {**batch_at(dc, step), **ex}
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            tput = args.batch * args.seq * (step + 1 - start_step) / max(time.time() - t0, 1e-9)
            print(f"step {step+1:5d}  loss {loss:7.4f}  grad_norm {float(metrics['grad_norm']):8.3f}  tok/s {tput:9.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state, async_write=False)
        if args.fail_at_step and step + 1 == args.fail_at_step:
            print(f"INJECTED FAILURE at step {step+1} (resume with the same --ckpt-dir)")
            sys.exit(17)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    print("training complete")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
