"""Serving driver: batched prefill + greedy decode with a KV/state cache.

Demonstrates the inference path on CPU smoke configs; the full configs'
prefill/decode steps lower at production scale via launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.model import build
from repro.serve.serve_step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = build(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(42)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros((args.batch, 4, cfg.d_model), jnp.bfloat16)
        batch["mrope_pos"] = jnp.tile(
            jnp.arange(args.prompt_len, dtype=jnp.int32)[None, None], (3, args.batch, 1)
        )
    if cfg.family == "encdec":
        batch["enc_feats"] = jnp.zeros((args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
    t0 = time.time()
    toks = greedy_generate(
        model, params, batch, steps=args.gen, pad_to=args.prompt_len + args.gen
    )
    dt = time.time() - t0
    print(f"arch={cfg.name}  generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", jax.numpy.asarray(toks[0])[:12])
    return toks


if __name__ == "__main__":
    main()
