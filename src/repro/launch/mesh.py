"""Production mesh construction.

(16, 16) = one pod of 256 chips (data × model over ICI);
(2, 16, 16) = two pods (pod axis over DCN).  Defined as a function so that
importing this module never touches jax device state.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh over however many (fake) devices a test session has."""
    return make_mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    return mesh.devices.size


def make_serve_mesh(n_devices: int | None = None):
    """Flat 1-D serving mesh over the host's local devices, axis ``jobs``.

    The serving job axis (core/controller.py ``sharded_job_mega_fn``,
    serve/snn_serve.py) is embarrassingly parallel — no collectives inside
    a round, each device runs its job shard's while_loop independently —
    so the mesh is one axis wide and sized to whatever devices this host
    actually has (or ``n_devices``, e.g. under
    ``--xla_force_host_platform_device_count``).
    """
    import jax

    n = n_devices or len(jax.devices())
    return make_mesh((n,), ("jobs",))
