"""Production mesh construction.

(16, 16) = one pod of 256 chips (data × model over ICI);
(2, 16, 16) = two pods (pod axis over DCN).  Defined as a function so that
importing this module never touches jax device state.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh over however many (fake) devices a test session has."""
    return make_mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    return mesh.devices.size
