"""Production mesh construction.

(16, 16) = one pod of 256 chips (data × model over ICI);
(2, 16, 16) = two pods (pod axis over DCN).  Defined as a function so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh over however many (fake) devices a test session has."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def chips(mesh) -> int:
    return mesh.devices.size
