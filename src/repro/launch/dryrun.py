import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices host the production meshes; every cell's step function is
``jax.jit(...).lower(**ShapeDtypeStructs).compile()``-ed, and the compiled
artifact's memory_analysis / cost_analysis / collective schedule are recorded
as a JSON artifact per cell (consumed by benchmarks/bench_roofline.py and
EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full sweep, cached
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.common import named, shape_dtypes, shardings
from repro.configs import ARCH_IDS, SHAPES, all_cells, get_config, skipped_cells
from repro.launch.mesh import chips, make_production_mesh
from repro.models.model import build, cache_specs, input_specs
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, state_specs
from repro.analysis import roofline as RL

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _spec_shardings(mesh, spec_tree):
    return shardings(spec_tree, mesh)


def _pspec_shardings(mesh, pspec_tree, sds_tree):
    return jax.tree.map(lambda ps, _: named(mesh, ps), pspec_tree, sds_tree)


def lower_cell(arch: str, shape_name: str, mesh, donate: bool = True, opt: bool = False):
    """Build and lower one cell. Returns (lowered, meta)."""
    import dataclasses

    import jax.numpy as _jnp

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind in ("prefill", "decode"):
        # serving deployments store weights in bf16
        cfg = dataclasses.replace(cfg, params_dtype=_jnp.bfloat16)
    if opt:
        # beyond-paper §Perf configuration (EXPERIMENTS.md §Perf):
        # flash train attention + mixed-precision norms.  ("save_dots"
        # selective remat was tried and refuted — compute term improved but
        # the saved-activation traffic raised the dominant memory term.)
        over = {"fast_norm": True}
        if cfg.n_heads:
            over["attn_impl"] = "flash"
        cfg = dataclasses.replace(cfg, **over)
    model = build(cfg, tp=mesh.shape["model"])
    inputs, in_pspecs = input_specs(cfg, shape)
    in_shard = _pspec_shardings(mesh, in_pspecs, inputs)

    if shape.kind == "train":
        oc = OptConfig(moments_dtype=cfg.moments_dtype)
        sspecs = state_specs(model, oc)
        step = make_train_step(model, oc, accum_steps=shape.accum_steps, mesh=mesh)
        state_sds = shape_dtypes(sspecs)
        state_shard = _spec_shardings(mesh, sspecs)
        fn = jax.jit(
            step,
            in_shardings=(state_shard, in_shard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,) if donate else (),
        )
        lowered = fn.lower(state_sds, inputs)
    elif shape.kind == "prefill":
        pspecs_params = _spec_shardings(mesh, model.specs)
        csds, cps = cache_specs(cfg, shape, tp=mesh.shape["model"])
        cache_shard = _pspec_shardings(mesh, cps, csds)
        fn = jax.jit(
            lambda p, b: model.prefill(p, b, mesh=mesh),
            in_shardings=(pspecs_params, in_shard),
            out_shardings=(cache_shard, None),
        )
        lowered = fn.lower(shape_dtypes(model.specs), inputs)
    else:  # decode
        pspecs_params = _spec_shardings(mesh, model.specs)
        csds, cps = cache_specs(cfg, shape, tp=mesh.shape["model"])
        cache_shard = _pspec_shardings(mesh, cps, csds)
        fn = jax.jit(
            lambda p, c, b, pos: model.decode_step(p, c, b, pos, mesh=mesh),
            in_shardings=(pspecs_params, cache_shard, in_shard, None),
            out_shardings=(None, cache_shard),
            donate_argnums=(1,) if donate else (),
        )
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = fn.lower(shape_dtypes(model.specs), csds, inputs, pos_sds)
    return lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True, opt: bool = False):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = lower_cell(arch, shape_name, mesh, opt=opt)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(compiled.memory_analysis())
        print({k: v for k, v in compiled.cost_analysis().items()
               if k in ("flops", "bytes accessed")})
        rl = RL.from_compiled(compiled)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mf = RL.model_flops(cfg, shape, shape.kind)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips(mesh),
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_state_bytes_per_chip": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "roofline": rl.to_dict(),
        "model_flops_global": mf,
        "model_flops_per_chip": mf / chips(mesh),
        "useful_flop_ratio": (mf / chips(mesh)) / max(rl.flops, 1.0),
    }
    if verbose:
        print(
            f"[{arch} × {shape_name} × {mesh_kind}] compile {t_compile:.1f}s  "
            f"args/chip {mem.argument_size_in_bytes/2**30:.2f} GiB  "
            f"temp/chip {mem.temp_size_in_bytes/2**30:.2f} GiB  "
            f"bottleneck {rl.bottleneck}  t={rl.t_bound*1e3:.2f} ms  "
            f"useful-flop-ratio {rec['useful_flop_ratio']:.2f}"
        )
    return rec


def artifact_path(arch, shape_name, mesh_kind, tag="baseline"):
    return ART_DIR / tag / f"{arch}__{shape_name}__{mesh_kind}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true", help="sweep all cells × meshes")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--opt", action="store_true", help="§Perf beyond-paper config")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        for a, s in all_cells():
            for mk in ("single", "multi"):
                todo.append((a, s, mk))
    else:
        assert args.arch and args.shape
        todo.append((args.arch, args.shape, args.mesh))

    failures = []
    for a, s, mk in todo:
        path = artifact_path(a, s, mk, args.tag)
        if path.exists() and not args.force:
            print(f"[skip cached] {a} × {s} × {mk}")
            continue
        path.parent.mkdir(parents=True, exist_ok=True)
        try:
            rec = run_cell(a, s, mk, opt=args.opt)
        except Exception as e:  # record the failure; the sweep continues
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "mesh": mk, "ok": False, "error": repr(e)[:2000]}
            failures.append((a, s, mk))
        path.write_text(json.dumps(rec, indent=1))
    for a, s, reason in skipped_cells():
        path = artifact_path(a, s, "skip", args.tag)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps({"arch": a, "shape": s, "ok": True, "skipped": reason}))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
