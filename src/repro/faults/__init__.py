"""Device-resident fault injection for the neuromorphic VP.

Real CIM crossbars are analog devices: stuck-at cells, conductance drift,
dead neurons, and dropped AER events are the non-idealities an architect
budgets for before silicon.  This package turns every existing workload
into a resilience benchmark: ``build()/build_snn(faults=FaultConfig(...))``
injects seeded, deterministic faults *inside* the jitted megaloop, and
``faults=None`` compiles the whole subsystem out (the ``obs=None`` pattern
— the config is a static field of ``VPConfig``, so it keys the function
cache and every fault branch is resolved at trace time).

Three hardware layers, three fault families:

**Crossbar faults** (structural, frozen at build time): stuck-at-0 /
stuck-at-1 cells, per-cell bit flips, and whole row/column failures are
compiled into two masks per unit — ``w_eff = (w & f_and) ^ f_xor`` — and
applied at *read* time inside ``kernels/crossbar_vmm`` and
``kernels/lif_step`` (ref, Pallas kernel, and ops wrappers all take the
same masks, so oracle and kernel agree bit-exactly).  Masking at read time
rather than baking faulted weights means reprogramming a crossbar row over
MMIO (``CIM_REG_WROW``) cannot heal a stuck cell — exactly like hardware.

**Neuron faults** (structural): dead neurons (never fire, membrane pinned
to 0) and per-neuron threshold drift (a signed offset added to the
programmed threshold, clamped >= 1), applied in the LIF update and,
symmetrically, in the VP's termination predicate so a drifted/dead network
still quiesces correctly.

**Transport faults** (dynamic, decided per spike event): seeded drop /
duplication of AER spike messages at the consumption point, plus the
graceful-degradation overflow policy ``on_overflow="drop"`` that converts
the inbox/outbox watermark from a fatal ``RuntimeError`` into counted,
traced spike loss.

Determinism contract
--------------------
Dynamic fault decisions hash *simulation coordinates*, never execution
order: a spike's fate is ``hash(seed, unit_uid, axon, tick)`` where
``unit_uid`` is a placement-invariant unit identity and ``tick`` is the
LIF tick that consumes the spike.  Those coordinates are identical across
all four backends, every segmentation, every quantum, and fused vs
per-round dispatch — so a fixed seed yields bit-identical fault sites and
results everywhere (the conformance suite pins this).  The hash is a
counter-based PRNG (a murmur3-style 32-bit finalizer): statistically flat,
trivially reproducible, and stateless-by-coordinates; the seed itself
rides the megaloop carry as per-segment state so injection lives entirely
on device.  Thresholding the *same* hash at different rates makes drop
sets nested (common random numbers): raising ``p_spike_drop`` only ever
drops a superset of spikes, which is what makes degradation curves
near-monotone instead of noisy.

Structural fault sites are drawn host-side at build from
``numpy.random.default_rng(hash(seed, unit_uid))`` — again keyed by unit
identity, not placement, so re-segmenting the same network faults the
same cells.

See docs/faults.md for the full model and ``degradation_sweep`` for the
accuracy-vs-fault-rate driver.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "FaultConfig",
    "hash_u32",
    "unit_masks",
    "fidelity",
    "degradation_sweep",
]

_GOLDEN = np.uint32(0x9E3779B9)
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault model for one platform build.  Frozen + hashable: it is
    carried as a static field of ``VPConfig``, keys the controller's
    function cache, and every ``faults is None`` / rate-is-zero branch is
    resolved at trace time (zero cost when off).

    Rates are probabilities in [0, 1].  Structural rates (crossbar +
    neuron) are sampled once at build per unit; transport rates are
    evaluated per spike event on device.

    on_overflow:
      "raise" — (default) channel/store watermark trips abort the run with
                an actionable RuntimeError, exactly as without faults;
      "drop"  — inbox/outbox overflow becomes graceful degradation: excess
                spikes are discarded deterministically (highest-slack
                first, identically on every backend), counted in
                ``lost_total`` / ``outbox_lost`` and traced as
                ``spikes_dropped`` events.  Store-log overflow and late
                MMIO stay fatal — those are program bugs, not load.
    """

    seed: int = 0
    # -- crossbar (per cell / row / column, sampled at build) --
    p_stuck0: float = 0.0      # cell conductance stuck at zero
    p_stuck1: float = 0.0      # cell stuck at full-scale (int8 -1 pattern)
    p_bitflip: float = 0.0     # one random weight bit inverted per cell
    p_row_fail: float = 0.0    # whole wordline dead (row reads as 0)
    p_col_fail: float = 0.0    # whole bitline dead (column reads as 0)
    # -- neuron (per LIF row, sampled at build) --
    p_dead: float = 0.0        # neuron never fires, membrane pinned to 0
    p_thresh_drift: float = 0.0  # neuron's threshold drifts by +-drift_max
    thresh_drift_max: int = 4  # uniform in [-max, +max], clamped >= 1 total
    # -- transport (per spike event, decided on device) --
    p_spike_drop: float = 0.0  # AER spike silently lost in flight
    p_spike_dup: float = 0.0   # AER spike delivered twice (charge doubled)
    on_overflow: str = "raise"  # "raise" | "drop"

    def __post_init__(self):
        for f in ("p_stuck0", "p_stuck1", "p_bitflip", "p_row_fail",
                  "p_col_fail", "p_dead", "p_thresh_drift",
                  "p_spike_drop", "p_spike_dup"):
            v = getattr(self, f)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"FaultConfig.{f}={v!r}: rate must be in [0, 1]")
        if self.on_overflow not in ("raise", "drop"):
            raise ValueError(
                f"FaultConfig.on_overflow={self.on_overflow!r}: "
                "expected 'raise' or 'drop'")

    # static trace-time gates: which state arrays exist / which code paths
    # are stitched into the compiled step
    @property
    def has_xbar_faults(self) -> bool:
        return (self.p_stuck0 > 0 or self.p_stuck1 > 0 or self.p_bitflip > 0
                or self.p_row_fail > 0 or self.p_col_fail > 0)

    @property
    def has_neuron_faults(self) -> bool:
        return self.p_dead > 0 or self.p_thresh_drift > 0

    @property
    def has_transport_faults(self) -> bool:
        return self.p_spike_drop > 0 or self.p_spike_dup > 0

    @property
    def drop_overflow(self) -> bool:
        return self.on_overflow == "drop"


# ---------------------------------------------------------------------------
# counter-based PRNG: hash simulation coordinates -> uint32
# ---------------------------------------------------------------------------

def hash_u32(*keys):
    """Murmur3-style finalizer over integer keys -> uniform uint32.

    Works on scalars and jnp arrays alike (numpy semantics with wraparound
    via explicit uint32 casts).  The decision for a spike event is
    ``hash_u32(seed, uid, axon, tick) < p * 2**32`` — pure coordinates, no
    sequence state, hence identical on every backend / dispatch shape.
    """
    import jax.numpy as jnp

    h = jnp.uint32(_GOLDEN)
    for k in keys:
        h = (h ^ jnp.asarray(k).astype(jnp.uint32)) * jnp.uint32(_C1)
        h = h ^ (h >> 13)
        h = h * jnp.uint32(_C2)
        h = h ^ (h >> 16)
    return h


def threshold_u32(p: float) -> int:
    """Acceptance threshold for ``hash_u32(...) < threshold_u32(p)``.

    Plain Python int (fits uint32); comparing the *same* hash against
    thresholds for increasing p yields nested event sets (CRN), which keeps
    degradation curves monotone."""
    return min(int(float(p) * 4294967296.0), 4294967295)


def _host_hash(*keys) -> int:
    """Host-side uint32 hash (same function as hash_u32, numpy scalars)."""
    h = int(_GOLDEN)
    for k in keys:
        h = ((h ^ (int(k) & 0xFFFFFFFF)) * int(_C1)) & 0xFFFFFFFF
        h ^= h >> 13
        h = (h * int(_C2)) & 0xFFFFFFFF
        h ^= h >> 16
    return h


# ---------------------------------------------------------------------------
# structural fault sites (host-side, at build)
# ---------------------------------------------------------------------------

def unit_masks(fc: FaultConfig, uid: int, rows: int, cols: int, xbar: int):
    """Draw one unit's structural fault sites; returns a dict of numpy
    arrays shaped to the full crossbar (``xbar`` x ``xbar``):

      f_and  int8  (xbar, xbar) — AND mask: 0 where stuck-at-0/row/col dead
      f_xor  int8  (xbar, xbar) — XOR mask: stuck-at-1 pattern + bit flips
      f_dead bool  (xbar,)      — dead neurons (LIF rows)
      f_dth  int32 (xbar,)      — per-neuron threshold drift offsets

    ``w_eff = (w & f_and) ^ f_xor`` composes every crossbar fault: stuck-0
    and row/column failures clear bits via AND; stuck-at-1 first clears the
    cell (AND 0) then XORs in the full-scale pattern, so reprogramming the
    weight cannot change a stuck cell's effective value; bit flips XOR one
    random bit.  Faults land only inside the unit's configured
    ``rows x cols`` region — a stuck-at-1 outside it would charge ghost
    neurons the network never wired.

    Seeded from ``(fc.seed, uid)`` where uid is placement-invariant, so the
    same logical unit faults identically under every segmentation.
    """
    rng = np.random.default_rng(_host_hash(fc.seed, uid, 0x5EED))
    f_and = np.full((xbar, xbar), -1, np.int8)   # all bits set
    f_xor = np.zeros((xbar, xbar), np.int8)
    f_dead = np.zeros((xbar,), bool)
    f_dth = np.zeros((xbar,), np.int32)
    r, c = int(rows), int(cols)
    if r > 0 and c > 0:
        u = rng.random((r, c))
        stuck0 = u < fc.p_stuck0
        stuck1 = (u >= fc.p_stuck0) & (u < fc.p_stuck0 + fc.p_stuck1)
        flip = rng.random((r, c)) < fc.p_bitflip
        row_dead = rng.random(r) < fc.p_row_fail
        col_dead = rng.random(c) < fc.p_col_fail
        dead_cell = stuck0 | row_dead[:, None] | col_dead[None, :]
        a = np.where(dead_cell | stuck1, 0, -1).astype(np.int8)
        x = np.where(stuck1 & ~dead_cell, -1, 0).astype(np.int8)
        bits = (1 << rng.integers(0, 8, (r, c))).astype(np.int64)
        x = (x.astype(np.int64) ^ np.where(flip, bits, 0)).astype(np.int8)
        f_and[:r, :c] = a
        f_xor[:r, :c] = x
        f_dead[:r] = rng.random(r) < fc.p_dead
        drift = rng.integers(-fc.thresh_drift_max, fc.thresh_drift_max + 1, r)
        f_dth[:r] = np.where(rng.random(r) < fc.p_thresh_drift, drift, 0)
    return {"f_and": f_and, "f_xor": f_xor, "f_dead": f_dead, "f_dth": f_dth}


def apply_masks(weights, f_and, f_xor):
    """``w_eff = (w & f_and) ^ f_xor`` — the read-time crossbar fault view
    (jnp or numpy, int8 in / int8 out)."""
    return (weights & f_and) ^ f_xor


# ---------------------------------------------------------------------------
# degradation metric + sweep driver
# ---------------------------------------------------------------------------

def fidelity(counts, expected) -> float:
    """Output fidelity in [0, 1]: 1 - L1(counts, expected) / L1(expected).

    1.0 means the faulted run reproduced the fault-free oracle's output
    spike counts exactly; 0.0 means the error mass matched or exceeded the
    oracle's total output activity.  Deliberately coarse — it is a
    *degradation* metric for sweeps, not a task accuracy."""
    counts = np.asarray(counts, np.int64)
    expected = np.asarray(expected, np.int64)
    denom = max(int(np.abs(expected).sum()), 1)
    err = int(np.abs(counts - expected).sum())
    return max(0.0, 1.0 - err / denom)


def degradation_sweep(job, rates, *, fault_kind="transport", seed=0,
                      strategy="uniform", n_segments=2, n_units=None,
                      backend="vmap", quantum=32, max_rounds=2000,
                      check_every=2, fused=True, on_overflow="raise",
                      base=None, **build_kw):
    """Accuracy-vs-fault-rate curve for an SNN job: for each rate build the
    platform with a ``FaultConfig`` scaled to that rate, run it to
    completion, and score output fidelity against the job's fault-free
    oracle expectations.

    fault_kind selects which rate axis sweeps:
      "transport" — p_spike_drop = rate (AER events lost in flight)
      "crossbar"  — p_stuck0 = rate     (synapse cells stuck at zero)
      "neuron"    — p_dead = rate       (LIF neurons dead)
    ``base`` (a FaultConfig) seeds every other field — e.g. pass
    ``FaultConfig(on_overflow="drop")`` to sweep under graceful overflow.

    Returns a list of dicts, one per rate:
      ``{"rate", "fidelity", "total_spikes", "rounds", "counts"}``
    Fidelity at rate 0.0 is exact (1.0) by the conformance guarantee; the
    nested-CRN hash makes the transport curve near-monotone in rate.
    """
    from repro import snn
    from repro.core.controller import Controller

    base = base or FaultConfig()
    field = {"transport": "p_spike_drop", "crossbar": "p_stuck0",
             "neuron": "p_dead"}[fault_kind]
    if n_units is None:
        n_units = snn.n_units_for(job.layers)
    descs = snn.segmentation_for(n_units, strategy, n_segments=n_segments)
    out = []
    for rate in rates:
        fc = dataclasses.replace(base, seed=seed, on_overflow=on_overflow,
                                 **{field: float(rate)})
        if not (fc.has_xbar_faults or fc.has_neuron_faults
                or fc.has_transport_faults or fc.drop_overflow):
            fc = None  # rate 0 with default policy: compile faults out
        cfg, states, pending, meta = snn.build_snn(
            job.layers, descs, job.raster, edges=job.edges,
            n_ticks=job.n_ticks, faults=fc, **build_kw)
        ctl = Controller(cfg, states, pending, backend=backend,
                         quantum=quantum)
        rounds, _ = ctl.run(max_rounds=max_rounds, check_every=check_every,
                            fused=fused)
        counts = snn.output_spike_counts(ctl.result_states(), meta)
        out.append({
            "rate": float(rate),
            "fidelity": fidelity(counts, job.expected_counts),
            "total_spikes": int(snn.total_spikes(ctl.result_states())),
            "rounds": int(rounds),
            "counts": np.asarray(counts, np.int64),
        })
    return out
