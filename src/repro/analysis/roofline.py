"""Roofline-term extraction from compiled dry-run artifacts.

Three terms (seconds, per step), TPU v5e constants:

  compute    = per-chip HLO FLOPs / peak FLOP/s          (197 TF bf16)
  memory     = per-chip HLO bytes accessed / HBM BW      (819 GB/s)
  collective = per-chip collective bytes / ICI link BW   (~50 GB/s/link)

``cost_analysis()`` on an SPMD-partitioned module reports per-device numbers
(verified empirically), so no further division by chip count is needed.
Collective bytes are NOT in cost_analysis: we parse the compiled HLO text and
sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_COLL_NAMES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?.*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)"
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind operand bytes + counts from (compiled) HLO text."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLL_NAMES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_shapes, kind, operands = m.groups()
        if "-done(" in line:  # async pair: count the start only
            continue
        b = _shape_bytes(operands)
        if b == 0:  # operand types not inlined: fall back to result shape
            b = _shape_bytes(result_shapes)
            if kind == "all-gather":  # result is gathered: operand = result / groupsize
                pass  # conservative upper bound
        out[kind]["bytes"] += b
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items() if isinstance(v, dict))
    return out


@dataclass
class Roofline:
    flops: float  # per chip
    bytes_accessed: float  # per chip
    coll_bytes: float  # per chip
    coll_detail: dict = field(default_factory=dict)

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self):
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "coll_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_detail": self.coll_detail,
        }


def from_compiled(compiled) -> Roofline:
    """Loop-aware per-device cost (see hlo_cost.py): XLA's cost_analysis
    counts scan bodies once, so we re-derive totals with trip multipliers."""
    from repro.analysis import hlo_cost

    txt = compiled.as_text()
    c = hlo_cost.analyze(txt)
    xla = compiled.cost_analysis()
    detail = {k: round(v) for k, v in c.coll_by_kind.items()}
    detail["xla_flops_no_loops"] = float(xla.get("flops", 0.0))
    detail["xla_bytes_no_loops"] = float(xla.get("bytes accessed", 0.0))
    return Roofline(
        flops=float(c.flops),
        bytes_accessed=float(c.bytes),
        coll_bytes=float(c.coll),
        coll_detail=detail,
    )


def active_params(cfg) -> int:
    """Active parameter count per token (for MODEL_FLOPS = 6·N_active·D)."""
    from repro.models.model import build

    from repro.common import param_count

    m = build(cfg)
    total = param_count(m.specs)
    if cfg.moe is None:
        return total
    # subtract inactive expert params
    mo = cfg.moe
    n_moe_layers = cfg.n_layers - mo.first_k_dense
    per_expert = 3 * cfg.d_model * mo.d_ff_expert
    total_expert = n_moe_layers * mo.n_experts * per_expert
    active_expert = n_moe_layers * mo.top_k * per_expert
    return total - total_expert + active_expert


def model_flops(cfg, shape, kind: str) -> float:
    """6·N_active·D where D = tokens processed by the step."""
    n = active_params(cfg)
    if kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d  # forward only
    d = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * d
