"""Loop-aware cost accounting over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation **once** — a
``jax.lax.scan`` over 88 layers contributes its body a single time (verified
empirically in this repo), which would understate FLOPs by ~two orders of
magnitude for scanned models.  This module re-derives per-device cost with
loop multipliers:

- parse the HLO text into named computations and an instruction-name → shape
  map (operand shapes are not inlined in post-optimization HLO);
- per computation accumulate
  * ``flops`` — ``dot`` results × 2 × contraction size (lhs shape lookup),
  * ``bytes`` — result + operand bytes of memory-moving ops; instructions
    *inside* fusion computations never touch HBM, so fusion internals count
    for FLOPs only while the fusion call-site counts once for bytes,
  * ``coll``  — operand bytes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute (async ``-start`` counted, ``-done``
    skipped);
- roll up through the call graph; ``while`` bodies multiply by the trip count
  from ``backend_config known_trip_count`` (exact for jax scans), falling
  back to the loop condition's compare constant.

All numbers are per-device (the module is already SPMD-partitioned).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OP_AFTER_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")


def _parse_rhs(rhs: str):
    """Split '<result-type> <opname>(<args>), <attrs>' robustly.

    Result types may be tuples spanning many shapes (with /*index=N*/
    comments already stripped); find the op name as the token preceding the
    first paren after the result type.
    """
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        result_text, rest = rhs[: end + 1], rhs[end + 1 :].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        result_text, rest = rhs[:sp], rhs[sp + 1 :].lstrip()
    m = _OP_AFTER_RE.match(rest)
    if not m:
        return None
    return result_text, m.group(1), rest[m.end():]
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|true_computation|false_computation|"
    r"branch_computations|to_apply)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "copy-start", "slice", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "broadcast", "transpose",
    "reduce", "reduce-window", "concatenate", "pad", "reverse", "sort",
    "cholesky", "triangular-solve", "rng", "exponential", "tanh", "add",
    "multiply", "subtract", "divide", "maximum", "minimum", "select", "convert",
    "rsqrt", "sqrt", "log", "negate", "abs", "power", "compare", "and", "or",
    "xor", "clamp", "floor", "ceil", "sign", "cosine", "sine", "iota",
    "custom-call", "bitcast-convert",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "reshape", "while", "call", "conditional", "partition-id",
             "replica-id", "opt-barrier", "domain"}


def _shape_elems_bytes(text: str):
    elems, total = 0, 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclass
class _Instr:
    name: str
    op: str
    result_text: str
    args_text: str
    attrs_text: str


@dataclass
class Comp:
    name: str
    instrs: list = field(default_factory=list)
    is_entry: bool = False


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    trip_counts: list = field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0, bytes_too: bool = True):
        self.flops += other.flops * mult
        if bytes_too:
            self.bytes += other.bytes * mult
            self.coll += other.coll * mult
            for k, v in other.coll_by_kind.items():
                self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


def _split_args(rhs_after_op: str):
    """Split 'a, b), attrs...' at the matching close paren."""
    depth = 1
    for i, ch in enumerate(rhs_after_op):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rhs_after_op[:i], rhs_after_op[i + 1 :]
    return rhs_after_op, ""


class Module:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, Comp] = {}
        self.shape_of: dict[str, str] = {}
        self.entry: str | None = None
        cur = None
        for line in hlo_text.splitlines():
            hdr = _COMP_HDR.match(line)
            if hdr and "->" in line:
                cur = Comp(hdr.group(1), is_entry=line.lstrip().startswith("ENTRY"))
                self.comps[cur.name] = cur
                if cur.is_entry:
                    self.entry = cur.name
                continue
            if cur is None or line.strip() == "}":
                continue
            mi = _INSTR_RE.match(_COMMENT_RE.sub("", line))
            if not mi:
                continue
            name, rhs = mi.groups()
            parsed = _parse_rhs(rhs)
            if parsed is None:
                continue
            result_text, op, after = parsed
            args_text, attrs_text = _split_args(after)
            self.shape_of[name] = result_text
            cur.instrs.append(_Instr(name, op, result_text, args_text, attrs_text))

    def _operand_shapes(self, instr: _Instr):
        return [self.shape_of.get(n, "") for n in _OPERAND_RE.findall(instr.args_text)]

    def _dot_flops(self, instr: _Instr) -> float:
        out_elems, _ = _shape_elems_bytes(instr.result_text)
        ops = self._operand_shapes(instr)
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs_text)
        if m and ops:
            lhs_shapes = _SHAPE_RE.findall(ops[0])
            if lhs_shapes:
                lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",") if x]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
        return 2.0 * out_elems * k

    def local_cost(self, comp: Comp, in_fusion: bool):
        c = Cost()
        calls = []  # (name, kind)
        whiles = []  # (body, trip)
        for ins in comp.instrs:
            op = ins.op
            for cm in _CALLED_RE.finditer(ins.attrs_text):
                names = [n.strip().lstrip("%") for n in cm.group(1).split(",")]
                for n in names:
                    calls.append((n, op))
            if op == "while":
                m = _TRIP_RE.search(ins.attrs_text)
                trip = int(m.group(1)) if m else None
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs_text)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs_text)
                if body:
                    whiles.append((body.group(1), cond.group(1) if cond else None, trip))
                calls = [(n, k) for (n, k) in calls if k != "while"]
                continue
            if op in ("dot", "convolution"):
                c.flops += self._dot_flops(ins)
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                _, b = _shape_elems_bytes(" ".join(self._operand_shapes(ins)))
                if b == 0:
                    _, b = _shape_elems_bytes(ins.result_text)
                c.coll += b
                c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + b
                c.bytes += b
                continue
            if op in _MEM_OPS and not in_fusion:
                _, rb = _shape_elems_bytes(ins.result_text)
                if op in ("slice", "dynamic-slice", "gather"):
                    c.bytes += 2 * rb  # reads + writes only the slice
                elif op == "dynamic-update-slice":
                    ops_shapes = self._operand_shapes(ins)
                    _, ub = _shape_elems_bytes(ops_shapes[1] if len(ops_shapes) > 1 else "")
                    c.bytes += 2 * ub  # reads the update, writes the slice (in-place buffer)
                else:
                    _, ob = _shape_elems_bytes(" ".join(self._operand_shapes(ins)))
                    c.bytes += rb + ob
        return c, calls, whiles


def analyze(hlo_text: str) -> Cost:
    mod = Module(hlo_text)
    memo: dict[tuple[str, bool], Cost] = {}

    def fallback_trip(cond_name):
        comp = mod.comps.get(cond_name)
        if not comp:
            return 1
        consts = []
        for ins in comp.instrs:
            consts += [int(x) for x in re.findall(r"constant\((\d+)\)", ins.args_text + ins.attrs_text + ins.result_text)]
        return max(consts) if consts else 1

    def cost_of(name: str, in_fusion: bool, depth=0) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        memo[key] = total
        comp = mod.comps.get(name)
        if comp is None or depth > 128:
            return total
        local, calls, whiles = mod.local_cost(comp, in_fusion)
        total.add(local)
        for callee, kind in calls:
            child_fusion = in_fusion or kind == "fusion"
            sub = cost_of(callee, child_fusion, depth + 1)
            total.add(sub, 1.0)
        for body, cond, trip in whiles:
            if trip is None:
                trip = fallback_trip(cond)
            total.trip_counts.append((body, trip))
            total.add(cost_of(body, in_fusion, depth + 1), float(trip))
            if cond:
                total.add(cost_of(cond, in_fusion, depth + 1), float(trip))
        return total

    if mod.entry is None:
        return Cost()
    return cost_of(mod.entry, False)
