"""Vectorized LIF neuron-pool state + the pure tick update.

A *pool* is one layer's worth of neurons (≤ one crossbar's rows on the VP).
State is a flat dict of int32 arrays so pools stack/vmap/scan cleanly, and
the update delegates to the fused-step oracle in ``kernels/lif_step/ref.py``
— the single definition of LIF semantics that the Pallas kernel, the
spike-mode CIM unit (vp/cim.py snn_tick) and the pure-jnp network oracle
(snn/workloads.py) all share.  Everything is integer arithmetic: bit-exact
equality between the VP simulation and this model is asserted, not approx.

Semantics per tick (positive-saturating LIF, TrueNorth/RANC lineage):
  v'      = max(v + W·s - leak, 0)        (synaptic charge, subtractive leak)
  fired   = (refrac == 0) & (v' >= thresh)
  v''     = 0 where fired                  (reset to rest)
  refrac' = refrac_period where fired, else max(refrac - 1, 0)
Neurons inside their refractory window neither integrate nor fire.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels.lif_step import ref as lif_ref


@dataclasses.dataclass(frozen=True)
class LIFParams:
    thresh: int = 64  # firing threshold (>= 1: termination + pad-lane safety)
    leak: int = 1  # subtractive leak per tick (>= 0: idle pools stay idle)
    refrac_period: int = 0  # ticks a neuron is silent after firing

    def __post_init__(self):
        assert self.thresh >= 1, "thresh must be >= 1"
        assert self.leak >= 0, "leak must be >= 0 (negative leak never settles)"
        assert 0 <= self.refrac_period < 16, "refrac packs into 4 register bits"


def pool_state(n: int):
    """Zero membrane state for a pool of ``n`` neurons."""
    return {
        "v": jnp.zeros((n,), jnp.int32),
        "refrac": jnp.zeros((n,), jnp.int32),
    }


def lif_step(state, weights, spikes_in, params: LIFParams):
    """One tick: (state, int8 (R, C) synapses, int32 (C,) spike counts) ->
    (state', fired int32 (R,))."""
    v2, refrac2, fired = lif_ref.lif_step(
        weights, spikes_in, state["v"], state["refrac"],
        jnp.int32(params.thresh), jnp.int32(params.leak),
        jnp.int32(params.refrac_period),
    )
    return {"v": v2, "refrac": refrac2}, fired


def lif_step_multi(state, weight_blocks, spike_blocks, params: LIFParams):
    """One tick with multi-source fan-in: per-edge synapse blocks.

    ``weight_blocks``: [(R, C_e) int8, ...] — one synapse matrix per in-edge
    (feed-forward, lateral, recurrent); ``spike_blocks``: the matching
    [(C_e,) int32, ...] spike-count vectors.  The per-edge charges are
    contracted independently and summed — bit-identical to one contraction
    of the horizontally concatenated matrix, because the fan-in clip is
    element-wise and the int32 matmul distributes over column blocks (the
    same identity the VP's column groups rely on, kernels/lif_step/ref.py).
    This is the single-pool primitive behind the cycle-aware network oracle
    (snn/workloads.py): on the VP each edge occupies a disjoint axon range
    of the destination crossbar, so summing per-edge charge here mirrors
    the hardware's axon-space concatenation exactly.
    """
    assert len(weight_blocks) == len(spike_blocks) and weight_blocks
    syn = sum(lif_ref.syn_charge(jnp.asarray(w, jnp.int8), jnp.asarray(s, jnp.int32))
              for w, s in zip(weight_blocks, spike_blocks))
    v2, refrac2, fired = lif_ref.lif_update(
        syn, state["v"], state["refrac"], jnp.int32(params.thresh),
        jnp.int32(params.leak), jnp.int32(params.refrac_period),
    )
    return {"v": v2, "refrac": refrac2}, fired
