"""Spiking-neural-network subsystem: the VP's second accelerator
programming model (event-driven AER spikes vs dense VMM offload).

Module map:
  neuron.py    — vectorized LIF pool state + the pure tick update (the
                 single source of LIF semantics, shared with the Pallas
                 kernel in kernels/lif_step/ and the spike-mode CIM unit)
  topology.py  — SNN-to-VP mapping: one layer per spike-mode crossbar,
                 inter-layer AER wiring, placement strategies (uniform /
                 load_oriented / auto), input-raster injection, readback
  workloads.py — rate-coded inference jobs + the pure-jnp network oracle
                 the VP is verified bit-exactly against

Related VP pieces: core/channel.py MSG_SPIKE (tick-bucketed AER events),
vp/isa.py CIM_REG_MODE, vp/cim.py snn_tick (quantum-boundary LIF
integration), benchmarks/bench_snn.py (spikes/sec per segmentation).
"""
from repro.snn.neuron import LIFParams, lif_step, pool_state
from repro.snn.topology import (
    SNNLayer,
    auto_segmentation_for,
    build_snn,
    output_spike_counts,
    segmentation_for,
    total_spikes,
)
from repro.snn.workloads import (
    SNNJob,
    oracle_run,
    random_snn,
    rate_encode,
    snn_inference_job,
)
