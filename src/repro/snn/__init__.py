"""Spiking-neural-network subsystem: the VP's second accelerator
programming model (event-driven AER spikes vs dense VMM offload).

Module map:
  neuron.py    — vectorized LIF pool state + the pure tick update (the
                 single source of LIF semantics, shared with the Pallas
                 kernel in kernels/lif_step/ and the spike-mode CIM unit)
  topology.py  — SNN-to-VP mapping: layers tiled onto spike-mode crossbars
                 (wide layers shard into row stripes + co-located column
                 groups), AER wiring for the full connectivity graph
                 (feed-forward chain + lateral synapses + backward
                 RecurrentEdge projections, each in-edge its own column
                 range), placement strategies (uniform / load_oriented /
                 auto / traffic-aware auto, cyclic edges costed),
                 spike-rate profiling, input-raster injection, readback
  workloads.py — rate-coded inference jobs (feed-forward and recurrent) +
                 the cycle-aware pure-jnp network oracle the VP is
                 verified bit-exactly against over a shared tick horizon
                 (oracle_rates is the profiling pass behind traffic-aware
                 placement)

Related VP pieces: core/channel.py MSG_SPIKE (tick-bucketed AER events),
vp/isa.py CIM_REG_MODE, vp/cim.py snn_tick (quantum-boundary LIF
integration), benchmarks/bench_snn.py (spikes/sec per segmentation),
repro.faults (seeded fault injection — ``build_snn(faults=...)`` and the
``degradation_sweep`` accuracy-vs-fault-rate driver re-exported here).
"""
from repro.faults import FaultConfig, degradation_sweep
from repro.snn.neuron import LIFParams, lif_step, lif_step_multi, pool_state
from repro.snn.topology import (
    RecurrentEdge,
    SNNLayer,
    StripeGroup,
    auto_segmentation_for,
    build_hybrid,
    build_snn,
    connectivity,
    consumed_rates,
    edge_dsts,
    hybrid_results,
    is_cyclic,
    layer_groups,
    measure_traffic,
    n_units_for,
    output_spike_counts,
    profile_traffic,
    segmentation_for,
    total_spikes,
)
from repro.snn.workloads import (
    HybridJob,
    SNNJob,
    hybrid_job,
    oracle_rates,
    oracle_run,
    random_recurrent_snn,
    random_snn,
    rate_encode,
    snn_inference_job,
    snn_recurrent_job,
    snn_skip_job,
)
