"""SNN benchmark workloads: rate-coded multi-layer LIF inference jobs +
the pure-jnp network oracle the VP simulation is verified against.

Timing contract shared with the VP mapping (snn/topology.py): one tick of
axonal delay per hop — *every* hop, whether the edge points forward along
the chain, sideways (lateral synapses), or backward (recurrent
projections).  Input timestep k is integrated by layer 0 at tick k; any
layer's spikes from tick j reach every destination of its out-edges at
tick j+1.  The oracle is therefore cycle-aware by construction: it holds
every layer's previous-tick spike vector and feeds each layer the
concatenation of its in-edge sources (``connectivity``), contracted
per-edge exactly like the VP's disjoint axon ranges
(``neuron.lif_step_multi``).

Horizons: a feed-forward chain simulates T + L + 1 ticks — after the input
ends, a layer can never fire again once its upstream goes quiet (leak >= 0
+ reset-to-zero), so output spike *counts* are exact regardless of when
the event-driven VP run terminates.  A cyclic network can self-sustain, so
the caller must pass an explicit ``n_ticks``; the VP runs the identical
bounded window (``build_snn(n_ticks=...)`` -> per-unit ``tick_limit``),
keeping VP-vs-oracle equality bit-exact.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.snn import topology
from repro.snn.neuron import LIFParams, lif_step_multi, pool_state
from repro.snn.topology import RecurrentEdge, SNNLayer, connectivity


def rate_encode(x, t_steps: int, seed: int = 0):
    """Rates x in [0, 1]^n -> Bernoulli spike raster, int (T, n)."""
    rng = np.random.default_rng(seed)
    x = np.clip(np.asarray(x, np.float64), 0.0, 1.0)
    return (rng.random((t_steps, x.shape[0])) < x).astype(np.int32)


def random_snn(layer_sizes=(64, 48, 10), seed: int = 0, w_lo: int = -4, w_hi: int = 8):
    """Feed-forward LIF chain with positive-biased random int8 synapses.

    Thresholds scale with fan-in so mid-rate input keeps every layer
    spiking (the traffic, not the task, is what the VP benchmarks need).
    """
    rng = np.random.default_rng(seed)
    layers = []
    for n_in, n_out in zip(layer_sizes[:-1], layer_sizes[1:]):
        w = rng.integers(w_lo, w_hi, (n_out, n_in)).astype(np.int8)
        layers.append(SNNLayer(w, LIFParams(thresh=max(n_in, 1), leak=1)))
    return layers


def random_recurrent_snn(layer_sizes=(48, 40, 12), seed: int = 0,
                         w_lo: int = -4, w_hi: int = 8, inhibition: int = 6):
    """Recurrent LIF network: ``random_snn``'s chain plus three kinds of
    cyclic connectivity (TrueNorth/RANC-style core workloads).

    - the last hidden layer is Elman-style self-recurrent: a random
      ``lateral`` matrix feeds its own spikes back one tick later;
    - the output layer is a winner-take-all pool: ``lateral`` inhibition
      (``-inhibition`` off-diagonal) suppresses the non-winning neurons;
    - the output projects *backward* onto the hidden layer
      (``RecurrentEdge``), closing a two-layer loop.

    Returns (layers, edges) for ``build_snn(..., edges=edges, n_ticks=...)``
    / ``oracle_run(..., edges=edges, n_ticks=...)``.
    """
    rng = np.random.default_rng(seed)
    sizes = list(layer_sizes)
    n_layers = len(sizes) - 1
    assert n_layers >= 2, "a recurrent job needs a hidden and an output layer"
    layers = []
    for i, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = rng.integers(w_lo, w_hi, (n_out, n_in)).astype(np.int8)
        if i == n_layers - 2:  # Elman hidden: mild random self-coupling
            lateral = rng.integers(-2, 3, (n_out, n_out)).astype(np.int8)
            thresh = max(n_in + n_out // 4, 1)
        elif i == n_layers - 1:  # WTA output: mutual lateral inhibition
            lateral = (-inhibition * (1 - np.eye(n_out, dtype=np.int64))).astype(np.int8)
            thresh = max(n_in, 1)
        else:
            lateral = None
            thresh = max(n_in, 1)
        layers.append(SNNLayer(w, LIFParams(thresh=thresh, leak=1), lateral=lateral))
    feedback = rng.integers(-2, 3, (sizes[-2], sizes[-1])).astype(np.int8)
    edges = (RecurrentEdge(src=n_layers - 1, dst=n_layers - 2, weights=feedback),)
    return layers, edges


def _oracle(layers, raster, edges=(), n_ticks=None):
    """Shared cycle-aware oracle loop; returns (output_counts,
    per_layer_totals, per_layer_per_neuron_totals, n_ticks)."""
    import jax.numpy as jnp

    t_steps, n_in = raster.shape
    n_layers = len(layers)
    assert layers[0].n_in == n_in
    in_edges, _, _ = connectivity(layers, edges)
    if n_ticks is None:
        assert not topology._cyclic(in_edges), (
            "cyclic connectivity can self-sustain: pass the n_ticks horizon "
            "(the VP runs the same bounded tick_limit)")
        n_ticks = t_steps + n_layers + 1
    assert t_steps <= n_ticks, "raster outlives the tick horizon"
    w_blocks = [[jnp.asarray(w) for _, w, _ in in_edges[l]]
                for l in range(n_layers)]
    states = [pool_state(l.n_out) for l in layers]
    prev = [jnp.zeros((l.n_out,), jnp.int32) for l in layers]
    per_neuron = [np.zeros(l.n_out, np.int64) for l in layers]
    totals = np.zeros(n_layers, np.int64)
    zero_in = jnp.zeros((n_in,), jnp.int32)
    for j in range(n_ticks):
        ext = jnp.asarray(raster[j], jnp.int32) if j < t_steps else zero_in
        # every layer sees *last* tick's spikes of every source (one tick
        # of axonal delay per hop, cyclic edges included)
        feeds = [[ext if src < 0 else prev[src] for src, _, _ in in_edges[l]]
                 for l in range(n_layers)]
        new_prev = []
        for l, layer in enumerate(layers):
            states[l], fired = lif_step_multi(
                states[l], w_blocks[l], feeds[l], layer.params
            )
            new_prev.append(fired)
            per_neuron[l] += np.asarray(fired, np.int64)
            totals[l] += int(fired.sum())
        prev = new_prev
    return per_neuron[-1].copy(), totals, per_neuron, n_ticks


def oracle_run(layers, raster, edges=(), n_ticks=None):
    """Pure-jnp reference simulation; returns (output_counts,
    per_layer_totals).  ``edges``/``n_ticks``: see the module docstring."""
    counts, totals, _, _ = _oracle(layers, raster, edges, n_ticks)
    return counts, totals


def oracle_rates(layers, raster, edges=(), n_ticks=None):
    """Profiling pass: per-layer per-neuron emitted-spike totals + the tick
    count — the inputs to snn/topology.profile_traffic's traffic matrix."""
    _, _, per_neuron, nt = _oracle(layers, raster, edges, n_ticks)
    return per_neuron, nt


@dataclasses.dataclass
class SNNJob:
    layers: list
    raster: np.ndarray
    expected_counts: np.ndarray  # oracle output spike counts
    expected_total: int  # oracle all-layer spike total
    edges: tuple = ()  # recurrent projections (RecurrentEdge, ...)
    n_ticks: int | None = None  # tick horizon (mandatory when cyclic)


def snn_inference_job(layer_sizes=(64, 48, 10), t_steps: int = 12,
                      rate: float = 0.5, seed: int = 0) -> SNNJob:
    """Rate-coded inference job: random input rates -> raster -> oracle."""
    rng = np.random.default_rng(seed + 1)
    layers = random_snn(layer_sizes, seed=seed)
    x = rng.random(layer_sizes[0]) * rate * 2
    raster = rate_encode(x, t_steps, seed=seed + 2)
    counts, totals = oracle_run(layers, raster)
    return SNNJob(layers, raster, counts, int(totals.sum()))


@dataclasses.dataclass
class HybridJob:
    """One platform, two concurrent workloads: a dense VMM offload job and
    a spiking network whose raster a live RISC-V CPU injects via MMIO
    (``CIM_REG_SPIKE``) and whose output counts it reads back
    (``CIM_REG_COUNTS``) — the paper's multicore-host-plus-accelerators
    co-simulation scenario.  Oracle expectations for both halves ride
    along; ``snn.build_hybrid(job, strategy)`` assembles the platform."""
    dense: object  # vp.workloads.Layer
    dense_expected: np.ndarray  # A @ B for the dense half
    snn: SNNJob  # layers + raster + oracle counts over an explicit horizon
    seed: int = 0


def hybrid_job(layer_sizes=(32, 24, 10), t_steps: int = 8, rate: float = 0.5,
               seed: int = 0, dense_layer=None, settle: int = 1) -> HybridJob:
    """Build the canonical hybrid workload: the conformance dense layer
    plus a rate-coded feed-forward SNN sized for CPU injection (layer 0 and
    the output layer each within one crossbar — the driver program targets
    one input tile and reads one output stripe).

    The tick horizon is explicit (``t_steps + depth + settle`` — with
    ``settle=1`` exactly the feed-forward oracle's own window), because the
    driver's count readback is *tick-addressed*: it asks for the counts as
    of that horizon, which is what makes the DMA'd values a pure function
    of the tick grid rather than of round timing."""
    from repro.vp import workloads as vwl

    dense = dense_layer or vwl.Layer("hybrid", "vmm", 8, 8, 4)
    _, _, o = vwl.layer_data(dense, seed)
    rng = np.random.default_rng(seed + 1)
    layers = random_snn(layer_sizes, seed=seed)
    x = rng.random(layer_sizes[0]) * rate * 2
    raster = rate_encode(x, t_steps, seed=seed + 2)
    n_ticks = t_steps + len(layers) + settle
    counts, totals = oracle_run(layers, raster, n_ticks=n_ticks)
    snn = SNNJob(layers, raster, counts, int(totals.sum()), n_ticks=n_ticks)
    return HybridJob(dense, o, snn, seed)


def snn_skip_job(layer_sizes=(32, 24, 16, 10), t_steps: int = 8,
                 rate: float = 0.5, seed: int = 0, w_lo: int = -4,
                 w_hi: int = 8) -> SNNJob:
    """Feed-forward chain plus a forward *skip* connection from the first
    hidden layer straight to the output layer (l -> l+k, a residual-style
    shortcut).  Still acyclic, so no tick horizon is needed — the network
    drains by itself, like the plain chain; the skip's spikes simply arrive
    one tick after emission like every hop (so the output integrates the
    shortcut path earlier than the deep path)."""
    assert len(layer_sizes) >= 4, "a skip needs dst > src + 1"
    rng = np.random.default_rng(seed + 3)
    layers = random_snn(layer_sizes, seed=seed)
    src, dst = 0, len(layers) - 1
    skip = RecurrentEdge(src=src, dst=dst, weights=rng.integers(
        w_lo, w_hi, (layers[dst].n_out, layers[src].n_out)).astype(np.int8))
    x = rng.random(layer_sizes[0]) * rate * 2
    raster = rate_encode(x, t_steps, seed=seed + 2)
    counts, totals = oracle_run(layers, raster, edges=(skip,))
    return SNNJob(layers, raster, counts, int(totals.sum()), edges=(skip,))


def snn_recurrent_job(layer_sizes=(48, 40, 12), t_steps: int = 10,
                      rate: float = 0.5, seed: int = 0,
                      settle: int = 6) -> SNNJob:
    """Recurrent inference job: a ``random_recurrent_snn`` network (Elman
    hidden recurrence, WTA output inhibition, output->hidden feedback)
    under a rate-coded raster, verified over a bounded tick horizon.

    ``settle`` extra ticks after the input window let the cycles ring; the
    horizon ``n_ticks = T + L + settle`` is part of the job — the VP ticks
    exactly that many times per unit and the oracle simulates the same
    window, so expected counts are exact even when the recurrent activity
    would self-sustain past it.
    """
    rng = np.random.default_rng(seed + 1)
    layers, edges = random_recurrent_snn(layer_sizes, seed=seed)
    x = rng.random(layer_sizes[0]) * rate * 2
    raster = rate_encode(x, t_steps, seed=seed + 2)
    n_ticks = t_steps + len(layers) + settle
    counts, totals = oracle_run(layers, raster, edges=edges, n_ticks=n_ticks)
    return SNNJob(layers, raster, counts, int(totals.sum()),
                  edges=edges, n_ticks=n_ticks)


def serve_request(layer_sizes=(16, 12, 8), *, t_steps: int = 6,
                  rate: float = 0.5, seed: int = 0, n_segments: int = 2,
                  strategy: str = "uniform", in_cap=None, out_cap=None,
                  faults=None):
    """One admission-ready serving request (serve/snn_serve.SnnRequest).

    Builds a rate-coded inference platform exactly as ``snn_inference_job``
    + ``build_snn`` would, and carries the fault-free oracle's output
    counts for end-to-end verification (for faulted requests the counts
    are the *fault-free* reference — what ``faults.fidelity`` compares
    degraded output against).  Requests built with the same
    ``layer_sizes``/``n_segments``/``strategy`` but different seeds,
    rates, durations, caps, or fault seeds share one compiled shape and
    therefore one serving bucket (docs/serving.md).
    """
    from repro.serve.snn_serve import SnnRequest

    job = snn_inference_job(layer_sizes, t_steps=t_steps, rate=rate,
                            seed=seed)
    descs = topology.segmentation_for(len(layer_sizes) - 1, strategy,
                                      n_segments=n_segments)
    cfg, states, pending, meta = topology.build_snn(
        job.layers, descs, job.raster, n_ticks=job.n_ticks,
        in_cap=in_cap, out_cap=out_cap, faults=faults)
    return SnnRequest(cfg, states, pending, meta,
                      expected_counts=tuple(int(c)
                                            for c in job.expected_counts))


def serve_fleet(n_requests: int, layer_sizes=(16, 12, 8), *, seed: int = 0,
                t_steps_choices=(4, 6, 8), rate: float = 0.5,
                n_segments: int = 2, strategy: str = "uniform",
                in_cap=None, out_cap=None, faults=None):
    """A heterogeneous request fleet sharing one compiled shape.

    Per-request weights, rasters, and durations all differ (seeded off
    ``seed``), which is exactly the serving case: the bucket key only sees
    the compiled shape, so the whole fleet batches.  Returns the requests
    in submission order.
    """
    rng = np.random.default_rng(seed)
    return [
        serve_request(layer_sizes,
                      t_steps=int(rng.choice(t_steps_choices)),
                      rate=rate, seed=seed + 7919 * (i + 1),
                      n_segments=n_segments, strategy=strategy,
                      in_cap=in_cap, out_cap=out_cap, faults=faults)
        for i in range(n_requests)
    ]
