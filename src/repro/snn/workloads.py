"""SNN benchmark workloads: rate-coded multi-layer LIF inference jobs +
the pure-jnp network oracle the VP simulation is verified against.

Timing contract shared with the VP mapping (snn/topology.py): one tick of
axonal delay per layer hop.  Input timestep k is integrated by layer 0 at
tick k; layer l's spikes from tick j reach layer l+1 at tick j+1.  The
oracle simulates T + L + 1 ticks — after the input ends, a layer can never
fire again once its upstream goes quiet (leak >= 0 + reset-to-zero), so
output spike *counts* are exact regardless of when the event-driven VP run
terminates.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.snn.neuron import LIFParams, lif_step, pool_state
from repro.snn.topology import SNNLayer


def rate_encode(x, t_steps: int, seed: int = 0):
    """Rates x in [0, 1]^n -> Bernoulli spike raster, int (T, n)."""
    rng = np.random.default_rng(seed)
    x = np.clip(np.asarray(x, np.float64), 0.0, 1.0)
    return (rng.random((t_steps, x.shape[0])) < x).astype(np.int32)


def random_snn(layer_sizes=(64, 48, 10), seed: int = 0, w_lo: int = -4, w_hi: int = 8):
    """Feed-forward LIF chain with positive-biased random int8 synapses.

    Thresholds scale with fan-in so mid-rate input keeps every layer
    spiking (the traffic, not the task, is what the VP benchmarks need).
    """
    rng = np.random.default_rng(seed)
    layers = []
    for n_in, n_out in zip(layer_sizes[:-1], layer_sizes[1:]):
        w = rng.integers(w_lo, w_hi, (n_out, n_in)).astype(np.int8)
        layers.append(SNNLayer(w, LIFParams(thresh=max(n_in, 1), leak=1)))
    return layers


def _oracle(layers, raster):
    """Shared oracle loop; returns (output_counts, per_layer_totals,
    per_layer_per_neuron_totals, n_ticks)."""
    import jax.numpy as jnp

    t_steps, n_in = raster.shape
    n_layers = len(layers)
    assert layers[0].n_in == n_in
    states = [pool_state(l.n_out) for l in layers]
    prev = [jnp.zeros((l.n_out,), jnp.int32) for l in layers]
    per_neuron = [np.zeros(l.n_out, np.int64) for l in layers]
    totals = np.zeros(n_layers, np.int64)
    zero_in = jnp.zeros((n_in,), jnp.int32)
    n_ticks = t_steps + n_layers + 1
    for j in range(n_ticks):
        feeds = [jnp.asarray(raster[j], jnp.int32) if j < t_steps else zero_in]
        feeds += prev[:-1]
        new_prev = []
        for l, layer in enumerate(layers):
            states[l], fired = lif_step(
                states[l], jnp.asarray(layer.weights), feeds[l], layer.params
            )
            new_prev.append(fired)
            per_neuron[l] += np.asarray(fired, np.int64)
            totals[l] += int(fired.sum())
        prev = new_prev
    return per_neuron[-1].copy(), totals, per_neuron, n_ticks


def oracle_run(layers, raster):
    """Pure-jnp reference simulation; returns (output_counts, per_layer_totals)."""
    counts, totals, _, _ = _oracle(layers, raster)
    return counts, totals


def oracle_rates(layers, raster):
    """Profiling pass: per-layer per-neuron emitted-spike totals + the tick
    count — the inputs to snn/topology.profile_traffic's traffic matrix."""
    _, _, per_neuron, n_ticks = _oracle(layers, raster)
    return per_neuron, n_ticks


@dataclasses.dataclass
class SNNJob:
    layers: list
    raster: np.ndarray
    expected_counts: np.ndarray  # oracle output spike counts
    expected_total: int  # oracle all-layer spike total


def snn_inference_job(layer_sizes=(64, 48, 10), t_steps: int = 12,
                      rate: float = 0.5, seed: int = 0) -> SNNJob:
    """Rate-coded inference job: random input rates -> raster -> oracle."""
    rng = np.random.default_rng(seed + 1)
    layers = random_snn(layer_sizes, seed=seed)
    x = rng.random(layer_sizes[0]) * rate * 2
    raster = rate_encode(x, t_steps, seed=seed + 2)
    counts, totals = oracle_run(layers, raster)
    return SNNJob(layers, raster, counts, int(totals.sum()))
