"""SNN-to-VP mapping: layers onto spike-mode CIM units across segments.

A feed-forward SNN maps one layer per crossbar: the layer's (n_out, n_in)
int8 synapse matrix becomes the unit's conductances, the layer's neurons
its rows.  Inter-layer connectivity is pure AER traffic: neuron j of layer
l firing at tick T becomes a MSG_SPIKE to layer l+1's unit (axon j) with
t_avail = T + channel latency, integrated at tick T+1 — one tick of axonal
delay per hop, *independent of placement*, because the builder enforces
``tick_period >= channel_latency`` (the same inequality the paper demands
of quantum vs latency).  The last layer is a sink: it counts its own spikes
instead of emitting events.

Placement strategies mirror the dense-VMM ones (core/segmentation.py):
``uniform`` spreads one unit per CPU segment, ``load_oriented`` packs units
into CIM-only segments, ``auto`` greedily balances per-layer synaptic-op
costs.  The whole network needs no CPU programs — every CPU halts at t=0
and the simulation is driven entirely by the event machinery, which is
exactly what makes SNNs the stress test for segmentation choices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segmentation as sg
from repro.vp import isa, platform as pf
from repro.snn.neuron import LIFParams


@dataclasses.dataclass(frozen=True)
class SNNLayer:
    weights: np.ndarray  # int8 (n_out, n_in) synapse matrix
    params: LIFParams = LIFParams()

    @property
    def n_out(self) -> int:
        return self.weights.shape[0]

    @property
    def n_in(self) -> int:
        return self.weights.shape[1]


def segmentation_for(n_layers: int, strategy: str, n_segments: int = 4):
    """Segment descriptors with >= n_layers CIM units under ``strategy``."""
    if strategy == "uniform":
        per = -(-n_layers // n_segments)
        descs = sg.uniform(n_cpus=n_segments, cims_per_cpu=per)
    elif strategy == "load_oriented":
        n_cim_segs = max(n_segments - 2, 1)
        per = -(-n_layers // n_cim_segs)
        descs = [sg.SegmentDesc(cpu=True, dram=True), sg.SegmentDesc(cpu=True)]
        descs += [sg.SegmentDesc(n_cims=per, cim_mgr=1) for _ in range(n_cim_segs)]
    elif strategy == "auto":
        raise ValueError("use auto_segmentation_for(layers, n_segments)")
    else:
        raise ValueError(strategy)
    assert sum(d.n_cims for d in descs) >= n_layers
    return descs


def auto_segmentation_for(layers, n_segments: int = 4, slots_per_seg: int = 2):
    """Greedy balanced placement over per-layer synaptic-op costs.

    Returns (descs, placement): longest-processing-time assignment of
    layers to segments (respecting the per-segment slot cap), plus the
    layer -> global-unit map that keeps the assignment — without it a
    cost-sorted greedy pass balances *units* while the layers land on
    them in chain order, which can be maximally imbalanced.
    """
    costs = [float(l.n_out * l.n_in) for l in layers]
    order = sorted(range(len(layers)), key=lambda i: -costs[i])
    n_seg = max(1, min(n_segments, len(layers)))
    assert n_seg * slots_per_seg >= len(layers), "not enough slots"
    loads = [0.0] * n_seg
    assign: list[list[int]] = [[] for _ in range(n_seg)]
    for i in order:
        open_segs = [s for s in range(n_seg) if len(assign[s]) < slots_per_seg]
        s = min(open_segs, key=lambda s: loads[s])
        assign[s].append(i)
        loads[s] += costs[i]
    descs, placement = [], {}
    g = 0
    for s in range(n_seg):
        descs.append(sg.SegmentDesc(cpu=(s == 0), dram=(s == 0),
                                    n_cims=len(assign[s]), cim_mgr=0))
        for layer_idx in assign[s]:
            placement[layer_idx] = g
            g += 1
    return descs, [placement[i] for i in range(len(layers))]


def build_snn(layers, descs, raster, *, placement=None, tick_period: int = 10_000,
              channel_latency: int = 10_000, local_latency: int = 64,
              use_kernel: bool = False):
    """Assemble a runnable SNN simulation.

    layers: [SNNLayer, ...] feed-forward chain
    descs: segment descriptors (segmentation_for / auto_segmentation_for)
    placement: layer index -> global CIM unit id (default: layer i on
        unit i; auto_segmentation_for returns the cost-balanced map)
    raster: int (T, n_in) input spike counts; timestep k is integrated at
        layer 0's tick k (injected as pre-scheduled AER events)
    Returns (cfg, states, pending, meta) ready for the Controller; meta
    locates the output unit for spike-count readback.
    """
    assert tick_period >= channel_latency >= local_latency, \
        "spike delivery must land within one tick under any placement"
    n_layers = len(layers)
    cim_seg, cim_slot = [], []
    for s, d in enumerate(descs):
        for k in range(d.n_cims):
            cim_seg.append(s)
            cim_slot.append(k)
    assert len(cim_seg) >= n_layers, "not enough CIM units for the layers"
    placement = list(placement) if placement is not None else list(range(n_layers))
    assert len(placement) == n_layers and len(set(placement)) == n_layers
    for i in range(1, n_layers):
        assert layers[i].n_in == layers[i - 1].n_out, "layer chain mismatch"

    crossbars = {placement[i]: np.asarray(l.weights, np.int8)
                 for i, l in enumerate(layers)}
    cim_init = {}
    for i, l in enumerate(layers):
        p = l.params
        g, g_next = placement[i], placement[i + 1] if i + 1 < n_layers else -1
        cim_init[g] = {
            "mode": isa.CIM_MODE_SPIKE,
            "rows": l.n_out,
            "cols": l.n_in,
            "thresh": p.thresh,
            "leak": p.leak,
            "refrac_period": p.refrac_period,
            "tick_period": tick_period,
            "next_tick": tick_period,  # global tick grid: P_k = (k+1)·period
            "dst_seg": cim_seg[g_next] if g_next >= 0 else -1,
            "dst_slot": cim_slot[g_next] if g_next >= 0 else 0,
            "axon_base": 0,
        }
    cfg, states, pending = sg.build(
        descs, crossbars=crossbars, cim_init=cim_init,
        channel_latency=channel_latency, local_latency=local_latency,
        use_kernel=use_kernel,
    )
    g0, g_out = placement[0], placement[-1]
    pending = _inject_raster(pending, cfg.n_segments, cim_seg[g0], cim_slot[g0],
                             raster, tick_period)
    meta = {
        "in_unit": (cim_seg[g0], cim_slot[g0]),
        "out_unit": (cim_seg[g_out], cim_slot[g_out]),
        "n_out": layers[-1].n_out,
        "unit_of_layer": [(cim_seg[placement[i]], cim_slot[placement[i]])
                          for i in range(n_layers)],
    }
    return cfg, states, pending, meta


def _inject_raster(pending, n_segments, seg0, slot0, raster, tick_period):
    """Pre-schedule the input spike train as AER events in seg0's inbox."""
    raster = np.asarray(raster)
    ts, axons = np.nonzero(raster)
    n = len(ts)
    assert n <= pf.IN_CAP // 2, \
        f"{n} input events overflow the inbox; shorten or thin the raster"
    boxes = {f: np.zeros((n_segments, pf.IN_CAP), np.int32)
             for f in ("kind", "addr", "data", "t_avail")}
    from repro.core import channel as ch
    boxes["kind"][seg0, :n] = ch.MSG_SPIKE
    boxes["addr"][seg0, :n] = (slot0 << 16) | axons
    boxes["data"][seg0, :n] = raster[ts, axons]
    boxes["t_avail"][seg0, :n] = (ts + 1) * tick_period
    valid = np.zeros((n_segments, pf.IN_CAP), bool)
    valid[seg0, :n] = True
    count = np.zeros((n_segments,), np.int32)
    count[seg0] = n
    out = {f: jnp.asarray(v) for f, v in boxes.items()}
    out["valid"] = jnp.asarray(valid)
    out["count"] = jnp.asarray(count)
    out["max_count"] = jnp.asarray(count)
    return jax.tree.map(lambda a, b: b, pending, out)


def output_spike_counts(states, meta) -> np.ndarray:
    """Per-neuron emitted-spike counts of the output layer."""
    s, k = meta["out_unit"]
    return np.asarray(states["cims"]["spike_counts"][s, k, : meta["n_out"]])


def total_spikes(states) -> int:
    """All spikes emitted by every unit over the whole run."""
    return int(np.asarray(states["cims"]["spikes_total"]).sum())
