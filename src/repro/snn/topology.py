"""SNN-to-VP mapping: layers onto spike-mode CIM units across segments.

A feed-forward SNN maps each layer onto one or more 256×256 crossbars.  A
layer that fits one crossbar becomes a single spike-mode unit: its
(n_out, n_in) int8 synapse matrix the unit's conductances, its neurons the
unit's rows.  A *wide* layer is tiled (Fig.: RANC/TrueNorth-style
multi-core layers):

  * rows (output neurons) shard into ≤256-neuron *stripes*; each stripe
    keeps its own membrane state and can be placed on any segment.  Input
    spikes fan out to every stripe; output spikes merge back by global
    neuron id (each stripe's ``axon_base`` offsets its rows into the
    downstream axon space).
  * columns (input axons) of a stripe whose fan-in exceeds 256 shard into
    a *column group* of co-located slots: the first tile (the owner) holds
    the stripe's neurons, the rest are contributor tiles that forward
    their partial synaptic charge to the owner within the same tick
    (vp/cim.py snn_tick).  Co-location makes the reduction tick-atomic, so
    sharded and unsharded layers are bit-identical.

Inter-layer connectivity is pure AER traffic: neuron j of layer l firing at
tick T becomes a MSG_SPIKE to each of layer l+1's stripes (the tile whose
column slice covers axon j) with t_avail = T + channel latency, integrated
at tick T+1 — one tick of axonal delay per hop, *independent of placement*,
because the builder enforces ``tick_period >= channel_latency`` (the same
inequality the paper demands of quantum vs latency).  A layer with no
out-edges is a sink: it counts its own spikes instead of emitting events.

Connectivity is not restricted to the forward chain (TrueNorth/RANC cores
are dominated by recurrent wiring): a layer may declare *lateral* synapses
(``SNNLayer.lateral``, intra-layer, e.g. winner-take-all inhibition) and
the network may declare backward *recurrent* projections
(``RecurrentEdge(src, dst, weights)`` with dst <= src, e.g. Elman-style
feedback).  Every in-edge of a layer occupies its own column range of the
layer's crossbar — the effective fan-in is the concatenation of all source
axon spaces (``connectivity``) — and every out-edge is just more fan-out
table entries, so cyclic spikes ride the identical tick-bucketed AER
machinery as forward ones: a spike emitted at tick k integrates at the
destination's tick k+1 whether the edge points forward, sideways, or
backward.  Because cyclic activity can self-sustain forever, cyclic nets
must declare a tick horizon (``build_snn(n_ticks=...)``): every unit ticks
exactly ``n_ticks`` times (``tick_limit``) and the cycle-aware oracle
(snn/workloads.py) simulates the same bounded window, keeping VP-vs-oracle
equality bit-exact.

Placement strategies mirror the dense-VMM ones (core/segmentation.py):
``uniform`` spreads units across CPU segments, ``load_oriented`` packs them
into CIM-only segments, ``auto`` balances per-group synaptic-op costs — or,
given a measured traffic matrix (``profile_traffic`` / ``measure_traffic``),
places shard groups to minimize cross-segment spike traffic under
per-segment slot budgets (core/segmentation.traffic_partition).  The whole
network needs no CPU programs — every CPU halts at t=0 and the simulation
is driven entirely by the event machinery, which is exactly what makes SNNs
the stress test for segmentation choices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segmentation as sg
from repro.vp import isa
from repro.vp import platform as pf
from repro.vp.cim import XBAR
from repro.snn.neuron import LIFParams


@dataclasses.dataclass(frozen=True)
class SNNLayer:
    weights: np.ndarray  # int8 (n_out, n_in) feed-forward synapse matrix
    params: LIFParams = LIFParams()
    # intra-layer lateral synapses, int8 (n_out, n_out): neuron j firing at
    # tick k contributes lateral[:, j] to its own layer's charge at tick
    # k+1 (one tick of axonal delay, like any hop).  None = none.
    lateral: np.ndarray | None = None

    @property
    def n_out(self) -> int:
        return self.weights.shape[0]

    @property
    def n_in(self) -> int:
        return self.weights.shape[1]


@dataclasses.dataclass(frozen=True)
class RecurrentEdge:
    """Extra projection: layer ``src``'s spikes feed layer ``dst`` (one
    tick later, like every hop).  ``weights`` is int8
    (layers[dst].n_out, layers[src].n_out).  ``dst <= src`` is a recurrent
    or lateral edge (``dst == src`` is equivalent to ``SNNLayer.lateral``);
    ``dst > src + 1`` is a forward *skip* connection (l -> l+k, e.g.
    residual-style shortcuts) — still acyclic, so no tick horizon needed
    unless some other edge closes a cycle."""
    src: int
    dst: int
    weights: np.ndarray


def connectivity(layers, edges=()):
    """Canonical connectivity table of a (possibly cyclic) network.

    Returns ``(in_edges, out_edges, eff_n_in)``:

      in_edges[l]  — ordered [(src, weights, col_off), ...]: the sources
                     whose concatenated axon spaces form layer l's crossbar
                     columns.  ``src == -1`` is the external input raster
                     (layer 0's feed-forward edge); ``src >= 0`` is layer
                     src's spike output, delayed one tick.  Order: the
                     feed-forward edge first (so external raster axons stay
                     at offset 0), then lateral, then declared recurrent
                     edges in declaration order.
      out_edges[l] — [(dst, col_off), ...]: where layer l's spikes land in
                     each destination's effective axon space.
      eff_n_in[l]  — layer l's effective fan-in (total crossbar columns).

    Both the VP builder (``build_snn``) and the cycle-aware oracle
    (snn/workloads.py) derive their wiring from this one table, which is
    what makes their axon spaces — and therefore the per-axon fan-in
    saturation — line up bit-exactly.
    """
    n_layers = len(layers)
    pairs = []  # (dst, src, weights) in canonical order
    for l, layer in enumerate(layers):
        pairs.append((l, l - 1, np.asarray(layer.weights, np.int8)))
        if layer.lateral is not None:
            lat = np.asarray(layer.lateral, np.int8)
            assert lat.shape == (layer.n_out, layer.n_out), (
                f"layer {l}: lateral must be (n_out, n_out) = "
                f"{(layer.n_out, layer.n_out)}, got {lat.shape}")
            pairs.append((l, l, lat))
    for e in edges:
        assert isinstance(e, RecurrentEdge), "edges must be RecurrentEdge"
        assert 0 <= e.dst < n_layers and 0 <= e.src < n_layers, (
            f"edge {e.src}->{e.dst}: both ends must name layers in "
            f"[0, {n_layers})")
        w = np.asarray(e.weights, np.int8)
        want = (layers[e.dst].n_out, layers[e.src].n_out)
        assert w.shape == want, (
            f"recurrent edge {e.src}->{e.dst}: weights must be {want} "
            f"(dst neurons x src neurons), got {w.shape}")
        pairs.append((e.dst, e.src, w))
    in_edges = [[] for _ in range(n_layers)]
    out_edges = [[] for _ in range(n_layers)]
    eff_n_in = [0] * n_layers
    for dst, src, w in sorted(pairs, key=lambda p: p[0]):  # stable in dst
        off = eff_n_in[dst]
        in_edges[dst].append((src, w, off))
        eff_n_in[dst] += w.shape[1]
        if src >= 0:
            out_edges[src].append((dst, off))
    return in_edges, out_edges, eff_n_in


def _cyclic(in_edges) -> bool:
    """Cyclicity predicate over an already-computed in-edge table: any
    in-edge pointing sideways or backward closes a cycle (the forward
    chain's src is always l-1)."""
    return any(src >= l for l, el in enumerate(in_edges) for src, _, _ in el)


def is_cyclic(layers, edges=()) -> bool:
    """True if any in-edge points sideways or backward (lateral synapses or
    recurrent projections) — such nets need an explicit tick horizon."""
    return _cyclic(connectivity(layers, edges)[0])


@dataclasses.dataclass(frozen=True)
class StripeGroup:
    """One placeable shard of a layer: a ≤256-neuron stripe together with
    the column tiles covering its full fan-in.  The group's ``width`` slots
    must be co-located (consecutive slots of one segment)."""
    layer: int
    stripe: int
    r0: int  # global output-neuron range [r0, r1) of the stripe
    r1: int
    col_edges: tuple  # ((c0, c1), ...) — input-axon slice per tile

    @property
    def width(self) -> int:
        return len(self.col_edges)

    @property
    def n_rows(self) -> int:
        return self.r1 - self.r0


def _tile(layers, eff_n_in) -> list:
    """Stripe groups from an already-computed effective-fan-in table."""
    groups = []
    for li, l in enumerate(layers):
        n_in = eff_n_in[li]
        col_edges = tuple(
            (c, min(c + XBAR, n_in)) for c in range(0, n_in, XBAR)
        )
        for si, r0 in enumerate(range(0, l.n_out, XBAR)):
            groups.append(
                StripeGroup(li, si, r0, min(r0 + XBAR, l.n_out), col_edges)
            )
    return groups


def layer_groups(layers, edges=()) -> list:
    """Tile every layer into stripe groups (row stripes × column tiles).

    Columns cover the layer's *effective* fan-in — the concatenated axon
    spaces of every in-edge (feed-forward, lateral, recurrent): a heavily
    recurrent layer tiles wider than its feed-forward shape suggests.
    """
    return _tile(layers, connectivity(layers, edges)[2])


def n_units_for(layers, edges=()) -> int:
    """Total CIM units (crossbar tiles) the network occupies."""
    return sum(g.width for g in layer_groups(layers, edges))


def _chunk_widths(widths, n_chunks):
    """Balanced contiguous partition of atomic group widths into ≤ n_chunks
    slot capacities.  Contiguity matters: ``build_snn``'s default first-fit
    placement walks groups in chain order, so exact consecutive chunks are
    filled with zero fragmentation — a column group can never be stranded.
    """
    caps = [0] * n_chunks
    total = sum(widths)
    s = 0
    for w in widths:
        caps[s] += w
        if s + 1 < n_chunks and caps[s] >= total / n_chunks:
            s += 1
    return caps


def segmentation_for(layers_or_n, strategy: str, n_segments: int = 4,
                     edges=()):
    """Segment descriptors with enough CIM slots for the network.

    ``layers_or_n``: the [SNNLayer, ...] chain (slot capacities follow its
    tiling, keeping every multi-crossbar column group placeable) or, for
    narrow single-unit layers, just the layer count.  ``edges``: recurrent
    projections (they widen effective fan-ins, hence the tiling).
    """
    if isinstance(layers_or_n, int):
        assert not edges, \
            "edges need the layer chain to size tiling: pass the layers"
        widths = [1] * layers_or_n
    else:
        widths = [g.width for g in layer_groups(layers_or_n, edges)]
    n_units = sum(widths)
    if strategy == "uniform":
        if isinstance(layers_or_n, int):  # historical equal split
            caps = [-(-n_units // n_segments)] * n_segments
        else:
            caps = _chunk_widths(widths, n_segments)
        descs = [sg.SegmentDesc(cpu=True, dram=(i == 0), n_cims=caps[i], cim_mgr=i)
                 for i in range(n_segments)]
    elif strategy == "load_oriented":
        n_cim_segs = max(n_segments - 2, 1)
        if isinstance(layers_or_n, int):
            caps = [-(-n_units // n_cim_segs)] * n_cim_segs
        else:
            caps = _chunk_widths(widths, n_cim_segs)
        descs = [sg.SegmentDesc(cpu=True, dram=True), sg.SegmentDesc(cpu=True)]
        descs += [sg.SegmentDesc(n_cims=caps[j], cim_mgr=1) for j in range(n_cim_segs)]
    elif strategy == "auto":
        raise ValueError("use auto_segmentation_for(layers, n_segments)")
    else:
        raise ValueError(strategy)
    assert sum(d.n_cims for d in descs) >= n_units
    return descs


def auto_segmentation_for(layers, n_segments: int = 4, slots_per_seg: int = 2,
                          traffic=None, edges=()):
    """Cost- or traffic-aware placement of shard groups onto segments.

    Without ``traffic``: greedy longest-processing-time assignment over
    per-group synaptic-op costs (rows × fan-in), respecting the
    per-segment slot cap and group atomicity.

    With ``traffic`` (a (G, G) measured spike-rate matrix from
    ``profile_traffic`` or ``measure_traffic``): delegates to
    ``core.segmentation.traffic_partition``, which minimizes the
    cross-segment spike-traffic cut under the same slot budgets; segments
    left empty are dropped, so heavy mutual traffic also shrinks the
    simulated platform.

    Returns (descs, placement): segment descriptors plus the group ->
    first-global-unit map ``build_snn`` consumes (for single-crossbar
    layers a group is a layer, so the map is the familiar layer -> unit
    list).  Without the explicit map a cost-sorted greedy pass balances
    *units* while the layers land on them in chain order, which can be
    maximally imbalanced.
    """
    _, _, eff_n_in = connectivity(layers, edges)
    groups = _tile(layers, eff_n_in)
    widths = [g.width for g in groups]
    # synaptic-op cost covers every in-edge: lateral/recurrent columns are
    # real crossbar work, so a recurrent layer weighs its full fan-in
    costs = [float(g.n_rows * eff_n_in[g.layer]) for g in groups]
    assert max(widths) <= slots_per_seg, \
        "a column group is atomic: raise slots_per_seg to its width"
    if traffic is not None:
        assign = sg.traffic_partition(widths, costs, traffic, n_segments,
                                      slots_per_seg)
    else:
        n_seg = max(1, min(n_segments, len(groups)))
        assert n_seg * slots_per_seg >= sum(widths), "not enough slots"
        order = sorted(range(len(groups)), key=lambda i: -costs[i])
        loads = [0.0] * n_seg
        used = [0] * n_seg
        assign = np.full(len(groups), -1, int)
        for i in order:
            open_segs = [s for s in range(n_seg)
                         if used[s] + widths[i] <= slots_per_seg]
            s = min(open_segs, key=lambda s: (loads[s], s))
            assign[i] = s
            used[s] += widths[i]
            loads[s] += costs[i]
    # compact to the segments actually used (traffic packing may empty some)
    live = sorted(set(int(s) for s in assign))
    remap = {s: i for i, s in enumerate(live)}
    descs, placement = [], np.zeros(len(groups), int)
    g = 0
    for s in live:
        members = [i for i in range(len(groups)) if assign[i] == s]
        w = sum(widths[i] for i in members)
        descs.append(sg.SegmentDesc(cpu=(remap[s] == 0), dram=(remap[s] == 0),
                                    n_cims=w, cim_mgr=0))
        for i in members:
            placement[i] = g
            g += widths[i]
    return descs, list(placement)


# ---------------------------------------------------------------------------
# traffic profiling


def profile_traffic(layers, raster, edges=(), n_ticks=None, injector=False):
    """Profiling pass over the pure-jnp oracle: per-group spike rates.

    Returns (rates, traffic): ``rates[i]`` = spikes/tick emitted by group
    i; ``traffic[i, j]`` = AER events/tick flowing from group i to group j
    (every spike a stripe emits becomes one event per destination stripe
    per out-edge — the tile it lands in is part of the same co-located
    group).  Cyclic edges are costed like any other: lateral synapses put
    rate on the same-layer block (including the diagonal — a stripe's
    spikes to itself are real channel traffic), recurrent projections on
    the backward block, and a layer feeding the same destination through
    several edges pays once per edge.

    ``injector=True`` (hybrid jobs, where a live CPU injects the raster
    through ``CIM_REG_SPIKE`` instead of pre-scheduled events): the matrix
    gains one trailing row/column for the *injector pseudo-group* — row =
    the MMIO injection stream into every layer-0 group (raster events/tick,
    replicated per stripe like the events themselves), column = the
    spike-count readback DMA out of each output-layer group.  Pin the
    pseudo-group to the CPU's segment via ``traffic_partition(pinned=...)``
    and CPU<->CIM MMIO traffic enters the cut like any spike traffic;
    ``rates`` keeps length G (the pseudo-group emits MMIO, not spikes).
    """
    from repro.snn.workloads import oracle_rates

    per_neuron, nt = oracle_rates(layers, raster, edges=edges, n_ticks=n_ticks)
    _, out_edges, eff_n_in = connectivity(layers, edges)
    groups = _tile(layers, eff_n_in)
    rates = np.array([
        per_neuron[g.layer][g.r0:g.r1].sum() / max(nt, 1) for g in groups
    ])
    traffic = _rates_to_traffic(groups, rates, _dsts_of(out_edges))
    if injector:
        g = len(groups)
        ext = np.zeros((g + 1, g + 1))
        ext[:g, :g] = traffic
        ev_rate = np.count_nonzero(np.asarray(raster)) / max(nt, 1)
        for gi, grp in enumerate(groups):
            if grp.layer == 0:
                ext[g, gi] = ev_rate  # CPU -> input tiles: injection stores
            if grp.layer == len(layers) - 1:
                ext[gi, g] += grp.n_rows / max(nt, 1)  # counts DMA back
        traffic = ext
    return rates, traffic


def measure_traffic(states, meta):
    """Traffic matrix from a completed VP run's per-unit spike counters.

    The measured analogue of ``profile_traffic``: run the workload once
    under any placement, then read each stripe owner's emitted-spike and
    tick counters out of the simulation state (``Controller.result_states``).
    ``meta`` carries the run's connectivity (``edge_dsts``), so cyclic
    edges are costed identically to the profiling pass.
    """
    groups = [g["group"] for g in meta["groups"]]
    cims = states["cims"]
    rates = []
    for info in meta["groups"]:
        seg, slot = info["units"][0]
        emitted = float(np.asarray(cims["spike_counts"][seg, slot]).sum())
        ticks = int(np.asarray(cims["ticks"][seg, slot]))
        rates.append(emitted / max(ticks, 1))
    rates = np.array(rates)
    return rates, _rates_to_traffic(groups, rates, meta["edge_dsts"])


def consumed_rates(states, meta):
    """Per-group *consumed*-spike rates (AER events integrated per tick).

    The receive-side complement of ``measure_traffic``'s emitted rates,
    read from the per-unit ``spikes_in`` counters (vp/cim.py) that
    ``_apply_inbox`` maintains.  Summed over a group's column tiles —
    every tile integrates its own axon slice, so the group total is the
    layer stripe's true fan-in traffic.  Emitted and consumed rates
    together give the overlap-aware traffic matrix ROADMAP item 2 asks
    for: emitted says what a stripe sends, consumed says what actually
    landed (dropped/mis-addressed events are the difference).
    """
    cims = states["cims"]
    rates = []
    for info in meta["groups"]:
        total = sum(float(np.asarray(cims["spikes_in"][seg, slot]))
                    for seg, slot in info["units"])
        seg, slot = info["units"][0]
        ticks = int(np.asarray(cims["ticks"][seg, slot]))
        rates.append(total / max(ticks, 1))
    return np.array(rates)


def _dsts_of(out_edges):
    return {l: [d for d, _ in out] for l, out in enumerate(out_edges) if out}


def edge_dsts(layers, edges=()):
    """Destination-layer multiset per source layer: {src: [dst, ...]} — one
    entry per out-edge (a layer feeding another through both the chain and
    a recurrent edge appears twice)."""
    return _dsts_of(connectivity(layers, edges)[1])


def _rates_to_traffic(groups, rates, edge_dsts_map):
    t = np.zeros((len(groups), len(groups)))
    for i, gi in enumerate(groups):
        dst_layers = edge_dsts_map.get(gi.layer, [])
        for j, gj in enumerate(groups):
            t[i, j] = rates[i] * dst_layers.count(gj.layer)
    return t


# ---------------------------------------------------------------------------
# builder


def _default_placement(groups, descs, reserved=None):
    """First-fit of groups (in chain order) onto segment slot capacity.

    ``reserved``: {segment: n_slots} already taken at the *front* of that
    segment's slot range (hybrid platforms reserve dense-mode units there);
    spike groups are placed after them."""
    caps = [d.n_cims for d in descs]
    base = np.concatenate([[0], np.cumsum(caps)])
    used = [int((reserved or {}).get(s, 0)) for s in range(len(descs))]
    placement = []
    for g in groups:
        for s in range(len(descs)):
            if caps[s] - used[s] >= g.width:
                placement.append(int(base[s]) + used[s])
                used[s] += g.width
                break
        else:
            raise AssertionError(
                f"no segment has {g.width} contiguous free CIM slots for "
                f"layer {g.layer} stripe {g.stripe}; widen the segmentation"
            )
    return placement


def _unit_tables(descs):
    """Global unit id -> (segment, slot) tables, walking descriptors in
    order — the numbering every builder and placement shares."""
    cim_seg, cim_slot = [], []
    for s, d in enumerate(descs):
        for k in range(d.n_cims):
            cim_seg.append(s)
            cim_slot.append(k)
    return cim_seg, cim_slot


def _snn_meta(layers, groups, placement, by_layer, out_edges, n_ticks,
              cim_seg, cim_slot):
    """The readback map shared by every SNN-carrying platform:
    ``output_spike_counts`` / ``measure_traffic`` consume these keys, so
    pure-SNN and hybrid builds must emit the identical contract."""
    n_layers = len(layers)
    unit_at = lambda gi, t=0: (cim_seg[placement[gi] + t],
                               cim_slot[placement[gi] + t])
    return {
        "in_unit": unit_at(by_layer[0][0]),
        "out_unit": unit_at(by_layer[n_layers - 1][0]),
        "n_out": layers[-1].n_out,
        "n_ticks": n_ticks,
        "edge_dsts": _dsts_of(out_edges),
        "out_groups": [
            (*unit_at(gi), groups[gi].r0, groups[gi].r1)
            for gi in by_layer[n_layers - 1]
        ],
        "unit_of_layer": [unit_at(by_layer[l][0]) for l in range(n_layers)],
        "groups": [
            {"group": groups[gi],
             "units": [unit_at(gi, t) for t in range(groups[gi].width)]}
            for gi in range(len(groups))
        ],
    }


def _wire_spike_units(layers, groups, placement, in_edges, out_edges,
                      cim_seg, cim_slot, tick_period, n_ticks):
    """Crossbar images + per-slot spike-mode presets for placed stripe
    groups — the single source of AER wiring, shared by ``build_snn`` and
    ``build_hybrid`` so pure-SNN and hybrid platforms wire bit-identically.

    Returns ``(crossbars, cim_init, placement, by_layer)`` keyed by global
    unit id; ``cim_seg``/``cim_slot`` are the platform's full unit tables
    (hybrid platforms interleave dense units — spike groups simply occupy
    the placement's slot runs, wherever they sit)."""
    n_layers = len(layers)
    by_layer = {}
    for gi, g in enumerate(groups):
        by_layer.setdefault(g.layer, []).append(gi)
    # one (n_out, eff_n_in) matrix per layer: every in-edge's columns in
    # canonical order — tiles slice this, the oracle contracts its blocks
    eff_w = [
        np.concatenate([w for _, w, _ in in_edges[l]], axis=1)
        for l in range(n_layers)
    ]
    placement = list(placement)
    assert len(placement) == len(groups), \
        "placement maps stripe groups (layer_groups order) to first unit ids"
    taken = set()
    for gi, g in enumerate(groups):
        run = range(placement[gi], placement[gi] + g.width)
        assert run.stop <= len(cim_seg), f"group {gi} placed past the last unit"
        assert len({cim_seg[u] for u in run}) == 1, \
            f"column group {gi} must be co-located in one segment"
        assert not taken.intersection(run), f"group {gi} overlaps another group"
        taken.update(run)

    # tile -> unit wiring: weights, neuron counts, fan-out tables.  One
    # fan-out entry per (out-edge, destination tile) pair — an edge's
    # column range in the destination's effective axon space starts at its
    # col_off, so a stripe's rows land at axon col_off + r0 + row there,
    # whether the edge points forward (the chain), sideways (lateral, the
    # destination may be this very unit), or backward (recurrent).
    crossbars, cim_init = {}, {}
    fanout = 1
    entries_of = {}  # owner unit -> [(seg, slot, axon_base, row_lo, row_hi)]
    for gi, g in enumerate(groups):
        owner = placement[gi]
        ent = []
        for dst_layer, col_off in out_edges[g.layer]:
            base = col_off + g.r0  # stripe's rows in dst's effective axons
            for gj in by_layer.get(dst_layer, []):
                nxt = groups[gj]
                for t, (c0, c1) in enumerate(nxt.col_edges):
                    lo, hi = max(0, c0 - base), min(g.n_rows, c1 - base)
                    if lo < hi:
                        u = placement[gj] + t
                        ent.append((cim_seg[u], cim_slot[u], base - c0, lo, hi))
        entries_of[owner] = ent
        fanout = max(fanout, len(ent))

    for gi, g in enumerate(groups):
        l = layers[g.layer]
        p = l.params
        owner = placement[gi]
        for t, (c0, c1) in enumerate(g.col_edges):
            u = owner + t
            crossbars[u] = np.asarray(eff_w[g.layer][g.r0:g.r1, c0:c1], np.int8)
            ent = entries_of[owner] if t == 0 else []
            pad = fanout - len(ent)
            cim_init[u] = {
                "mode": isa.CIM_MODE_SPIKE,
                "rows": g.n_rows if t == 0 else 0,
                "cols": c1 - c0,
                "thresh": p.thresh,
                "leak": p.leak,
                "refrac_period": p.refrac_period,
                "tick_period": tick_period,
                "next_tick": tick_period,  # global tick grid: P_k = (k+1)·period
                "tick_limit": 0 if n_ticks is None else int(n_ticks),
                "owner_slot": cim_slot[owner],
                "dst_seg": np.array([e[0] for e in ent] + [-1] * pad, np.int32),
                "dst_slot": np.array([e[1] for e in ent] + [0] * pad, np.int32),
                "axon_base": np.array([e[2] for e in ent] + [0] * pad, np.int32),
                "row_lo": np.array([e[3] for e in ent] + [0] * pad, np.int32),
                "row_hi": np.array([e[4] for e in ent] + [0] * pad, np.int32),
            }
    return crossbars, cim_init, placement, by_layer


def _fault_uids(groups, placement):
    """Placement-invariant unit identities for the fault PRNG
    (repro.faults): logical (layer, stripe, tile) coordinates rather than
    global unit ids, so re-segmenting or re-placing the same network draws
    the same structural fault sites and drops the same spikes."""
    uids = {}
    for gi, g in enumerate(groups):
        for t in range(g.width):
            uids[placement[gi] + t] = (g.layer << 16) | (g.stripe << 8) | t
    return uids


def build_snn(layers, descs, raster, *, edges=(), n_ticks: int | None = None,
              placement=None, tick_period: int = 10_000,
              channel_latency: int = 10_000, local_latency: int = 64,
              use_kernel: bool = False, in_cap: int | None = None,
              out_cap: int | None = None, faults=None):
    """Assemble a runnable SNN simulation.

    layers: [SNNLayer, ...] feed-forward chain (possibly with ``lateral``
        synapses); layers wider than one crossbar — in either dimension,
        counting every in-edge's columns — are tiled into stripe groups
        (see ``layer_groups``)
    edges: (RecurrentEdge, ...) extra projections — recurrent/lateral
        (dst <= src) or forward skip connections (dst > src + 1)
    n_ticks: tick horizon — every unit runs exactly ``n_ticks`` LIF ticks
        (``tick_limit``), matching the cycle-aware oracle's bounded window.
        Mandatory for cyclic connectivity (lateral or recurrent edges:
        activity can self-sustain, so an unbounded run may never
        terminate); optional for feed-forward chains (None = unlimited,
        the network drains by itself).
    descs: segment descriptors (segmentation_for / auto_segmentation_for)
    placement: group index -> first global CIM unit id; a group's ``width``
        units occupy consecutive slots of one segment (default: first-fit
        in chain order; auto_segmentation_for returns the balanced map).
        For single-crossbar layers this is the familiar layer -> unit list.
    raster: int (T, n_in) input spike counts; timestep k is integrated at
        layer 0's tick k (injected as pre-scheduled AER events)
    in_cap/out_cap: channel-box capacities (see ``segmentation.build``) —
        the inbox must hold the pre-scheduled raster events of its busiest
        segment in half its capacity; event-driven runs with short rasters
        can shrink both dramatically (the caps are the per-round cost on a
        CPU-free platform, and undersizing raises loudly)
    faults: ``repro.faults.FaultConfig`` or None — seeded fault injection
        (see docs/faults.md).  Unit identities given to the fault PRNG are
        logical (layer, stripe, tile) coordinates, so the same network
        faults identically under every segmentation and placement.
    Returns (cfg, states, pending, meta) ready for the Controller; meta
    locates the output units for spike-count readback.
    """
    assert tick_period >= channel_latency >= local_latency, \
        "spike delivery must land within one tick under any placement"
    n_layers = len(layers)
    for i in range(1, n_layers):
        assert layers[i].n_in == layers[i - 1].n_out, "layer chain mismatch"
    in_edges, out_edges, eff_n_in = connectivity(layers, edges)
    if n_ticks is None:
        assert not _cyclic(in_edges), (
            "cyclic connectivity (lateral or recurrent edges) can "
            "self-sustain: pass n_ticks to bound the run — the oracle "
            "(snn.oracle_run) takes the same horizon")
    else:
        assert n_ticks >= 1, "n_ticks must be >= 1"
        assert len(raster) <= n_ticks, (
            f"raster has {len(raster)} timesteps but the tick horizon is "
            f"{n_ticks}: later input would silently never integrate")
    groups = _tile(layers, eff_n_in)

    cim_seg, cim_slot = _unit_tables(descs)
    n_units = sum(g.width for g in groups)
    assert len(cim_seg) >= n_units, "not enough CIM units for the layers"
    if placement is None:
        placement = _default_placement(groups, descs)
    crossbars, cim_init, placement, by_layer = _wire_spike_units(
        layers, groups, placement, in_edges, out_edges, cim_seg, cim_slot,
        tick_period, n_ticks)
    cfg, states, pending = sg.build(
        descs, crossbars=crossbars, cim_init=cim_init,
        channel_latency=channel_latency, local_latency=local_latency,
        use_kernel=use_kernel, in_cap=in_cap, out_cap=out_cap,
        faults=faults, fault_uids=_fault_uids(groups, placement),
    )
    in_tiles = [
        [(cim_seg[placement[gi] + t], cim_slot[placement[gi] + t])
         for t in range(groups[gi].width)]
        for gi in by_layer[0]
    ]
    pending = _inject_raster(pending, cfg.n_segments, in_tiles, raster,
                             tick_period)
    meta = _snn_meta(layers, groups, placement, by_layer, out_edges, n_ticks,
                     cim_seg, cim_slot)
    return cfg, states, pending, meta


def _inject_raster(pending, n_segments, in_tiles, raster, tick_period):
    """Pre-schedule the input spike train as AER events.

    Every stripe of layer 0 integrates the full raster (row sharding fans
    inputs out), so each event is replicated once per stripe, addressed to
    the column tile covering its axon.  Events land in the inboxes of the
    segments hosting those tiles; each inbox keeps half its capacity free
    for runtime spike traffic.  The external edge is always the first of
    layer 0's in-edges (``connectivity``), so raster axon a is effective
    column a even when lateral/recurrent columns follow it.
    """
    raster = np.asarray(raster)
    ts, axons = np.nonzero(raster)
    vals = raster[ts, axons]
    seg_l, addr_l, data_l, t_l = [], [], [], []
    for tiles in in_tiles:
        segs = np.array([sk[0] for sk in tiles], np.int32)
        slots = np.array([sk[1] for sk in tiles], np.int32)
        tidx = axons // XBAR
        seg_l.append(segs[tidx])
        addr_l.append((slots[tidx] << 16) | (axons % XBAR))
        data_l.append(vals)
        t_l.append((ts + 1) * tick_period)
    ev = {
        "seg": np.concatenate(seg_l) if seg_l else np.zeros(0, np.int32),
        "addr": np.concatenate(addr_l) if addr_l else np.zeros(0, np.int32),
        "data": np.concatenate(data_l) if data_l else np.zeros(0, np.int32),
        "t": np.concatenate(t_l) if t_l else np.zeros(0, np.int32),
    }
    cap = pending["valid"].shape[1]  # the built platform's in_cap
    boxes = {f: np.zeros((n_segments, cap), np.int32)
             for f in ("kind", "addr", "data", "t_avail")}
    valid = np.zeros((n_segments, cap), bool)
    count = np.zeros((n_segments,), np.int32)
    from repro.core import channel as ch
    for s in range(n_segments):
        m = ev["seg"] == s
        n = int(m.sum())
        assert n <= cap // 2, \
            f"{n} input events overflow segment {s}'s inbox (cap {cap}); " \
            "shorten or thin the raster, or raise in_cap (wide layers " \
            "replicate events per stripe)"
        boxes["kind"][s, :n] = ch.MSG_SPIKE
        boxes["addr"][s, :n] = ev["addr"][m]
        boxes["data"][s, :n] = ev["data"][m]
        boxes["t_avail"][s, :n] = ev["t"][m]
        valid[s, :n] = True
        count[s] = n
    out = {f: jnp.asarray(v) for f, v in boxes.items()}
    out["valid"] = jnp.asarray(valid)
    out["count"] = jnp.asarray(count)
    out["max_count"] = jnp.asarray(count)
    # injected events are pre-scheduled, not routed: the routed-traffic
    # counter (obs/metrics.py) starts at zero, as does the overflow-loss
    # counter (the assert above guarantees injection itself never drops)
    out["routed_total"] = jnp.zeros((n_segments,), jnp.int32)
    out["lost_total"] = jnp.zeros((n_segments,), jnp.int32)
    return jax.tree.map(lambda a, b: b, pending, out)


def build_hybrid(job, strategy: str = "split", *, tick_period: int | None = None,
                 channel_latency: int = 10_000, local_latency: int = 64,
                 use_kernel: bool = False, in_cap: int | None = None,
                 out_cap: int | None = None, store_log: int | None = None,
                 faults=None):
    """Assemble the paper's headline co-simulation scenario: live RISC-V
    CPUs, dense-mode CIM units, and spike-mode CIM units in ONE platform.

    Segment 0's CPU drives the dense VMM offload over its two dense units
    (the familiar software-pipelined pair, ``vp.workloads.cim_workload``);
    a second CPU concurrently injects the SNN raster through tick-addressed
    ``CIM_REG_SPIKE`` stores, requests the output layer's spike counts back
    via ``CIM_REG_COUNTS`` once the tick horizon is reached, and copies
    them to shared DRAM (``vp.workloads.spike_driver_program``).  Both jobs
    share the same decoupled channels and quantum loop; the SNN side stays
    bit-identical to the pre-scheduled-raster path because injected spikes
    carry the same tick-grid ``t_avail`` as raster events.

    ``job``: a ``snn.hybrid_job(...)`` bundle (dense layer + SNNJob with an
    explicit ``n_ticks`` horizon + oracle expectations for both).

    strategy:
      split  — spike units in their own segments ({CPU0, DRAM, 2 dense},
               {CPU1}, up to 2 spike-unit segments) — Fig. 4b-style;
      packed — spike units co-located with the driver CPU (2 segments);
      auto   — CPU<->CIM MMIO traffic enters the placement cut: the
               profiling pass (``profile_traffic(injector=True)``) costs
               the injection and readback streams, ``traffic_partition``
               pins the injector pseudo-group to the driver CPU's segment,
               and spike groups pack to minimize cross-segment events.

    Returns (cfg, states, pending, meta).  ``meta`` carries the standard
    SNN readback map (``output_spike_counts`` works on it) plus ``o_word``
    and ``counts_word`` — where the dense result and the CPU-published
    spike counts sit in shared DRAM (``hybrid_results``).
    """
    from repro.vp import workloads as vwl

    layers, raster, edges = job.snn.layers, job.snn.raster, job.snn.edges
    n_layers = len(layers)
    n_ticks = job.snn.n_ticks
    assert n_ticks is not None, \
        "hybrid jobs need an explicit tick horizon (the readback target)"
    assert len(raster) <= n_ticks, "raster outlives the tick horizon"
    in_edges, out_edges, eff_n_in = connectivity(layers, edges)
    groups = _tile(layers, eff_n_in)
    widths = [g.width for g in groups]
    n_snn = sum(widths)
    in_gis = [gi for gi, g in enumerate(groups) if g.layer == 0]
    out_gis = [gi for gi, g in enumerate(groups) if g.layer == n_layers - 1]
    assert len(in_gis) == 1 and groups[in_gis[0]].width == 1, \
        "the spike driver targets one input tile: keep layer 0 in one crossbar"
    assert len(out_gis) == 1, \
        "the readback loop reads one output stripe: keep n_out <= 256"

    events = vwl.spike_events(raster)
    assert len(events) <= pf.SCRATCH_WORDS - vwl.EV_TABLE, \
        "event table overflows the driver CPU's scratch: thin the raster"
    if tick_period is None:
        # the injection deadline contract sizes the tick pitch: every tick-k
        # store must retire before (k+1)*period, and the driver injects the
        # whole table head-of-program, so one period covering the full loop
        # bounds every deadline (events are staged in timestep order)
        tick_period = max(channel_latency,
                          vwl.injection_cycles_bound(len(events)))

    dense_desc = sg.SegmentDesc(cpu=True, dram=True, n_cims=2, cim_mgr=0)
    if strategy == "split":
        caps = [c for c in _chunk_widths(widths, 2) if c]
        descs = [dense_desc, sg.SegmentDesc(cpu=True)] + [
            sg.SegmentDesc(n_cims=c, cim_mgr=1) for c in caps]
        placement = _default_placement(groups, descs, reserved={0: 2})
    elif strategy == "packed":
        descs = [dense_desc,
                 sg.SegmentDesc(cpu=True, n_cims=n_snn, cim_mgr=1)]
        placement = _default_placement(groups, descs, reserved={0: 2})
    elif strategy == "auto":
        _, traffic = profile_traffic(layers, raster, edges=edges,
                                     n_ticks=n_ticks, injector=True)
        costs = [float(g.n_rows * eff_n_in[g.layer]) for g in groups]
        slots = max(max(widths), -(-n_snn // 2))
        assign = sg.traffic_partition(
            widths + [0], costs + [0.0], traffic, n_segments=3,
            slots_per_seg=slots, pinned={len(groups): 0})
        members = {v: [i for i in range(len(groups)) if assign[i] == v]
                   for v in range(3)}
        descs, placement, unit = [dense_desc], [0] * len(groups), 2
        for v in range(3):  # virtual seg 0 = the driver CPU's segment
            w = sum(widths[i] for i in members[v])
            if v == 0:
                descs.append(sg.SegmentDesc(cpu=True, n_cims=w, cim_mgr=1))
            elif w:
                descs.append(sg.SegmentDesc(n_cims=w, cim_mgr=1))
            for i in members[v]:
                placement[i] = unit
                unit += widths[i]
    else:
        raise ValueError(strategy)

    cim_seg, cim_slot = _unit_tables(descs)
    crossbars, cim_init, placement, by_layer = _wire_spike_units(
        layers, groups, placement, in_edges, out_edges, cim_seg, cim_slot,
        tick_period, n_ticks)
    assert 0 not in crossbars and 1 not in crossbars, \
        "spike groups spilled into the reserved dense slots"

    ords = sg.mailbox_ordinals(descs)
    dense = vwl.cim_workload(job.dense, mgr_segments=[0],
                             cim_ids_per_mgr={0: (0, 1)}, seed=job.seed,
                             ordinals=ords)
    in_gid = placement[in_gis[0]]
    out_gid = placement[out_gis[0]]
    out_ord = ords[out_gid]
    assert sg.OUT0 + (out_ord + 1) * 256 <= vwl.EV_TABLE, \
        "output unit's mailbox OUT area would collide with the event table"
    counts_word = dense["o_word"] + job.dense.h * job.dense.p
    programs = dict(dense["programs"])
    programs[1] = vwl.spike_driver_program(
        sg.cim_global_base(in_gid), sg.cim_global_base(out_gid),
        len(events), n_ticks, layers[-1].n_out, out_ord, counts_word * 4)
    scratch = {s: dict(v) for s, v in dense["scratch"].items()}
    scratch.setdefault(1, {})[vwl.EV_TABLE] = events

    cfg, states, pending = sg.build(
        descs, programs=programs, dram_words=dense["dram"],
        crossbars={**dense["crossbars"], **crossbars},
        scratch_init=scratch, cim_init=cim_init,
        channel_latency=channel_latency, local_latency=local_latency,
        use_kernel=use_kernel, in_cap=in_cap, out_cap=out_cap,
        store_log=store_log, faults=faults,
        fault_uids=_fault_uids(groups, placement))
    meta = {
        **_snn_meta(layers, groups, placement, by_layer, out_edges, n_ticks,
                    cim_seg, cim_slot),
        "o_word": dense["o_word"],
        "counts_word": counts_word,
        "dense_shape": (job.dense.h, job.dense.p),
        "tick_period": tick_period,
    }
    return cfg, states, pending, meta


def hybrid_results(states, meta):
    """Both halves of a hybrid run, read from shared DRAM exactly as an
    external host would: (dense O matrix, CPU-published spike counts)."""
    h, p = meta["dense_shape"]
    dram = np.asarray(states["dram"]["data"][0])
    o = dram[meta["o_word"]: meta["o_word"] + h * p].reshape(h, p)
    counts = dram[meta["counts_word"]: meta["counts_word"] + meta["n_out"]]
    return o, counts


def output_spike_counts(states, meta) -> np.ndarray:
    """Per-neuron emitted-spike counts of the output layer, merged across
    its stripes by global neuron id."""
    counts = np.zeros(meta["n_out"], np.int64)
    for s, k, r0, r1 in meta["out_groups"]:
        counts[r0:r1] = np.asarray(states["cims"]["spike_counts"][s, k, : r1 - r0])
    return counts


def total_spikes(states) -> int:
    """All spikes emitted by every unit over the whole run."""
    return int(np.asarray(states["cims"]["spikes_total"]).sum())
