"""SNN-to-VP mapping: layers onto spike-mode CIM units across segments.

A feed-forward SNN maps each layer onto one or more 256×256 crossbars.  A
layer that fits one crossbar becomes a single spike-mode unit: its
(n_out, n_in) int8 synapse matrix the unit's conductances, its neurons the
unit's rows.  A *wide* layer is tiled (Fig.: RANC/TrueNorth-style
multi-core layers):

  * rows (output neurons) shard into ≤256-neuron *stripes*; each stripe
    keeps its own membrane state and can be placed on any segment.  Input
    spikes fan out to every stripe; output spikes merge back by global
    neuron id (each stripe's ``axon_base`` offsets its rows into the
    downstream axon space).
  * columns (input axons) of a stripe whose fan-in exceeds 256 shard into
    a *column group* of co-located slots: the first tile (the owner) holds
    the stripe's neurons, the rest are contributor tiles that forward
    their partial synaptic charge to the owner within the same tick
    (vp/cim.py snn_tick).  Co-location makes the reduction tick-atomic, so
    sharded and unsharded layers are bit-identical.

Inter-layer connectivity is pure AER traffic: neuron j of layer l firing at
tick T becomes a MSG_SPIKE to each of layer l+1's stripes (the tile whose
column slice covers axon j) with t_avail = T + channel latency, integrated
at tick T+1 — one tick of axonal delay per hop, *independent of placement*,
because the builder enforces ``tick_period >= channel_latency`` (the same
inequality the paper demands of quantum vs latency).  The last layer is a
sink: it counts its own spikes instead of emitting events.

Placement strategies mirror the dense-VMM ones (core/segmentation.py):
``uniform`` spreads units across CPU segments, ``load_oriented`` packs them
into CIM-only segments, ``auto`` balances per-group synaptic-op costs — or,
given a measured traffic matrix (``profile_traffic`` / ``measure_traffic``),
places shard groups to minimize cross-segment spike traffic under
per-segment slot budgets (core/segmentation.traffic_partition).  The whole
network needs no CPU programs — every CPU halts at t=0 and the simulation
is driven entirely by the event machinery, which is exactly what makes SNNs
the stress test for segmentation choices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segmentation as sg
from repro.vp import isa
from repro.vp.cim import XBAR
from repro.snn.neuron import LIFParams


@dataclasses.dataclass(frozen=True)
class SNNLayer:
    weights: np.ndarray  # int8 (n_out, n_in) synapse matrix
    params: LIFParams = LIFParams()

    @property
    def n_out(self) -> int:
        return self.weights.shape[0]

    @property
    def n_in(self) -> int:
        return self.weights.shape[1]


@dataclasses.dataclass(frozen=True)
class StripeGroup:
    """One placeable shard of a layer: a ≤256-neuron stripe together with
    the column tiles covering its full fan-in.  The group's ``width`` slots
    must be co-located (consecutive slots of one segment)."""
    layer: int
    stripe: int
    r0: int  # global output-neuron range [r0, r1) of the stripe
    r1: int
    col_edges: tuple  # ((c0, c1), ...) — input-axon slice per tile

    @property
    def width(self) -> int:
        return len(self.col_edges)

    @property
    def n_rows(self) -> int:
        return self.r1 - self.r0


def layer_groups(layers) -> list:
    """Tile every layer into stripe groups (row stripes × column tiles)."""
    groups = []
    for li, l in enumerate(layers):
        col_edges = tuple(
            (c, min(c + XBAR, l.n_in)) for c in range(0, l.n_in, XBAR)
        )
        for si, r0 in enumerate(range(0, l.n_out, XBAR)):
            groups.append(
                StripeGroup(li, si, r0, min(r0 + XBAR, l.n_out), col_edges)
            )
    return groups


def n_units_for(layers) -> int:
    """Total CIM units (crossbar tiles) the network occupies."""
    return sum(g.width for g in layer_groups(layers))


def _chunk_widths(widths, n_chunks):
    """Balanced contiguous partition of atomic group widths into ≤ n_chunks
    slot capacities.  Contiguity matters: ``build_snn``'s default first-fit
    placement walks groups in chain order, so exact consecutive chunks are
    filled with zero fragmentation — a column group can never be stranded.
    """
    caps = [0] * n_chunks
    total = sum(widths)
    s = 0
    for w in widths:
        caps[s] += w
        if s + 1 < n_chunks and caps[s] >= total / n_chunks:
            s += 1
    return caps


def segmentation_for(layers_or_n, strategy: str, n_segments: int = 4):
    """Segment descriptors with enough CIM slots for the network.

    ``layers_or_n``: the [SNNLayer, ...] chain (slot capacities follow its
    tiling, keeping every multi-crossbar column group placeable) or, for
    narrow single-unit layers, just the layer count.
    """
    if isinstance(layers_or_n, int):
        widths = [1] * layers_or_n
    else:
        widths = [g.width for g in layer_groups(layers_or_n)]
    n_units = sum(widths)
    if strategy == "uniform":
        if isinstance(layers_or_n, int):  # historical equal split
            caps = [-(-n_units // n_segments)] * n_segments
        else:
            caps = _chunk_widths(widths, n_segments)
        descs = [sg.SegmentDesc(cpu=True, dram=(i == 0), n_cims=caps[i], cim_mgr=i)
                 for i in range(n_segments)]
    elif strategy == "load_oriented":
        n_cim_segs = max(n_segments - 2, 1)
        if isinstance(layers_or_n, int):
            caps = [-(-n_units // n_cim_segs)] * n_cim_segs
        else:
            caps = _chunk_widths(widths, n_cim_segs)
        descs = [sg.SegmentDesc(cpu=True, dram=True), sg.SegmentDesc(cpu=True)]
        descs += [sg.SegmentDesc(n_cims=caps[j], cim_mgr=1) for j in range(n_cim_segs)]
    elif strategy == "auto":
        raise ValueError("use auto_segmentation_for(layers, n_segments)")
    else:
        raise ValueError(strategy)
    assert sum(d.n_cims for d in descs) >= n_units
    return descs


def auto_segmentation_for(layers, n_segments: int = 4, slots_per_seg: int = 2,
                          traffic=None):
    """Cost- or traffic-aware placement of shard groups onto segments.

    Without ``traffic``: greedy longest-processing-time assignment over
    per-group synaptic-op costs (rows × fan-in), respecting the
    per-segment slot cap and group atomicity.

    With ``traffic`` (a (G, G) measured spike-rate matrix from
    ``profile_traffic`` or ``measure_traffic``): delegates to
    ``core.segmentation.traffic_partition``, which minimizes the
    cross-segment spike-traffic cut under the same slot budgets; segments
    left empty are dropped, so heavy mutual traffic also shrinks the
    simulated platform.

    Returns (descs, placement): segment descriptors plus the group ->
    first-global-unit map ``build_snn`` consumes (for single-crossbar
    layers a group is a layer, so the map is the familiar layer -> unit
    list).  Without the explicit map a cost-sorted greedy pass balances
    *units* while the layers land on them in chain order, which can be
    maximally imbalanced.
    """
    groups = layer_groups(layers)
    widths = [g.width for g in groups]
    costs = [float(g.n_rows * layers[g.layer].n_in) for g in groups]
    assert max(widths) <= slots_per_seg, \
        "a column group is atomic: raise slots_per_seg to its width"
    if traffic is not None:
        assign = sg.traffic_partition(widths, costs, traffic, n_segments,
                                      slots_per_seg)
    else:
        n_seg = max(1, min(n_segments, len(groups)))
        assert n_seg * slots_per_seg >= sum(widths), "not enough slots"
        order = sorted(range(len(groups)), key=lambda i: -costs[i])
        loads = [0.0] * n_seg
        used = [0] * n_seg
        assign = np.full(len(groups), -1, int)
        for i in order:
            open_segs = [s for s in range(n_seg)
                         if used[s] + widths[i] <= slots_per_seg]
            s = min(open_segs, key=lambda s: (loads[s], s))
            assign[i] = s
            used[s] += widths[i]
            loads[s] += costs[i]
    # compact to the segments actually used (traffic packing may empty some)
    live = sorted(set(int(s) for s in assign))
    remap = {s: i for i, s in enumerate(live)}
    descs, placement = [], np.zeros(len(groups), int)
    g = 0
    for s in live:
        members = [i for i in range(len(groups)) if assign[i] == s]
        w = sum(widths[i] for i in members)
        descs.append(sg.SegmentDesc(cpu=(remap[s] == 0), dram=(remap[s] == 0),
                                    n_cims=w, cim_mgr=0))
        for i in members:
            placement[i] = g
            g += widths[i]
    return descs, list(placement)


# ---------------------------------------------------------------------------
# traffic profiling


def profile_traffic(layers, raster):
    """Profiling pass over the pure-jnp oracle: per-group spike rates.

    Returns (rates, traffic): ``rates[i]`` = spikes/tick emitted by group
    i; ``traffic[i, j]`` = AER events/tick flowing from group i to group j
    (every spike a stripe emits becomes one event per downstream stripe —
    the tile it lands in is part of the same co-located group).
    """
    from repro.snn.workloads import oracle_rates

    per_neuron, n_ticks = oracle_rates(layers, raster)
    groups = layer_groups(layers)
    rates = np.array([
        per_neuron[g.layer][g.r0:g.r1].sum() / max(n_ticks, 1) for g in groups
    ])
    return rates, _rates_to_traffic(groups, rates)


def measure_traffic(states, meta):
    """Traffic matrix from a completed VP run's per-unit spike counters.

    The measured analogue of ``profile_traffic``: run the workload once
    under any placement, then read each stripe owner's emitted-spike and
    tick counters out of the simulation state (``Controller.result_states``).
    """
    groups = [g["group"] for g in meta["groups"]]
    cims = states["cims"]
    rates = []
    for info in meta["groups"]:
        seg, slot = info["units"][0]
        emitted = float(np.asarray(cims["spike_counts"][seg, slot]).sum())
        ticks = int(np.asarray(cims["ticks"][seg, slot]))
        rates.append(emitted / max(ticks, 1))
    rates = np.array(rates)
    return rates, _rates_to_traffic(groups, rates)


def _rates_to_traffic(groups, rates):
    t = np.zeros((len(groups), len(groups)))
    for i, gi in enumerate(groups):
        for j, gj in enumerate(groups):
            if gj.layer == gi.layer + 1:
                t[i, j] = rates[i]
    return t


# ---------------------------------------------------------------------------
# builder


def _default_placement(groups, descs):
    """First-fit of groups (in chain order) onto segment slot capacity."""
    caps = [d.n_cims for d in descs]
    base = np.concatenate([[0], np.cumsum(caps)])
    used = [0] * len(descs)
    placement = []
    for g in groups:
        for s in range(len(descs)):
            if caps[s] - used[s] >= g.width:
                placement.append(int(base[s]) + used[s])
                used[s] += g.width
                break
        else:
            raise AssertionError(
                f"no segment has {g.width} contiguous free CIM slots for "
                f"layer {g.layer} stripe {g.stripe}; widen the segmentation"
            )
    return placement


def build_snn(layers, descs, raster, *, placement=None, tick_period: int = 10_000,
              channel_latency: int = 10_000, local_latency: int = 64,
              use_kernel: bool = False, in_cap: int | None = None,
              out_cap: int | None = None):
    """Assemble a runnable SNN simulation.

    layers: [SNNLayer, ...] feed-forward chain; layers wider than one
        crossbar are tiled into stripe groups (see ``layer_groups``)
    descs: segment descriptors (segmentation_for / auto_segmentation_for)
    placement: group index -> first global CIM unit id; a group's ``width``
        units occupy consecutive slots of one segment (default: first-fit
        in chain order; auto_segmentation_for returns the balanced map).
        For single-crossbar layers this is the familiar layer -> unit list.
    raster: int (T, n_in) input spike counts; timestep k is integrated at
        layer 0's tick k (injected as pre-scheduled AER events)
    in_cap/out_cap: channel-box capacities (see ``segmentation.build``) —
        the inbox must hold the pre-scheduled raster events of its busiest
        segment in half its capacity; event-driven runs with short rasters
        can shrink both dramatically (the caps are the per-round cost on a
        CPU-free platform, and undersizing raises loudly)
    Returns (cfg, states, pending, meta) ready for the Controller; meta
    locates the output units for spike-count readback.
    """
    assert tick_period >= channel_latency >= local_latency, \
        "spike delivery must land within one tick under any placement"
    n_layers = len(layers)
    for i in range(1, n_layers):
        assert layers[i].n_in == layers[i - 1].n_out, "layer chain mismatch"
    groups = layer_groups(layers)
    by_layer = {}
    for gi, g in enumerate(groups):
        by_layer.setdefault(g.layer, []).append(gi)

    cim_seg, cim_slot = [], []
    for s, d in enumerate(descs):
        for k in range(d.n_cims):
            cim_seg.append(s)
            cim_slot.append(k)
    n_units = sum(g.width for g in groups)
    assert len(cim_seg) >= n_units, "not enough CIM units for the layers"
    if placement is None:
        placement = _default_placement(groups, descs)
    placement = list(placement)
    assert len(placement) == len(groups), \
        "placement maps stripe groups (layer_groups order) to first unit ids"
    taken = set()
    for gi, g in enumerate(groups):
        run = range(placement[gi], placement[gi] + g.width)
        assert run.stop <= len(cim_seg), f"group {gi} placed past the last unit"
        assert len({cim_seg[u] for u in run}) == 1, \
            f"column group {gi} must be co-located in one segment"
        assert not taken.intersection(run), f"group {gi} overlaps another group"
        taken.update(run)

    # tile -> unit wiring: weights, neuron counts, fan-out tables
    crossbars, cim_init = {}, {}
    fanout = 1
    entries_of = {}  # owner unit -> [(seg, slot, axon_base, row_lo, row_hi)]
    for gi, g in enumerate(groups):
        owner = placement[gi]
        ent = []
        for gj in by_layer.get(g.layer + 1, []):
            nxt = groups[gj]
            for t, (c0, c1) in enumerate(nxt.col_edges):
                lo, hi = max(0, c0 - g.r0), min(g.n_rows, c1 - g.r0)
                if lo < hi:
                    u = placement[gj] + t
                    ent.append((cim_seg[u], cim_slot[u], g.r0 - c0, lo, hi))
        entries_of[owner] = ent
        fanout = max(fanout, len(ent))

    for gi, g in enumerate(groups):
        l = layers[g.layer]
        p = l.params
        owner = placement[gi]
        for t, (c0, c1) in enumerate(g.col_edges):
            u = owner + t
            crossbars[u] = np.asarray(l.weights[g.r0:g.r1, c0:c1], np.int8)
            ent = entries_of[owner] if t == 0 else []
            pad = fanout - len(ent)
            cim_init[u] = {
                "mode": isa.CIM_MODE_SPIKE,
                "rows": g.n_rows if t == 0 else 0,
                "cols": c1 - c0,
                "thresh": p.thresh,
                "leak": p.leak,
                "refrac_period": p.refrac_period,
                "tick_period": tick_period,
                "next_tick": tick_period,  # global tick grid: P_k = (k+1)·period
                "owner_slot": cim_slot[owner],
                "dst_seg": np.array([e[0] for e in ent] + [-1] * pad, np.int32),
                "dst_slot": np.array([e[1] for e in ent] + [0] * pad, np.int32),
                "axon_base": np.array([e[2] for e in ent] + [0] * pad, np.int32),
                "row_lo": np.array([e[3] for e in ent] + [0] * pad, np.int32),
                "row_hi": np.array([e[4] for e in ent] + [0] * pad, np.int32),
            }
    cfg, states, pending = sg.build(
        descs, crossbars=crossbars, cim_init=cim_init,
        channel_latency=channel_latency, local_latency=local_latency,
        use_kernel=use_kernel, in_cap=in_cap, out_cap=out_cap,
    )
    in_tiles = [
        [(cim_seg[placement[gi] + t], cim_slot[placement[gi] + t])
         for t in range(groups[gi].width)]
        for gi in by_layer[0]
    ]
    pending = _inject_raster(pending, cfg.n_segments, in_tiles, raster,
                             tick_period)
    unit_at = lambda gi, t=0: (cim_seg[placement[gi] + t],
                               cim_slot[placement[gi] + t])
    meta = {
        "in_unit": in_tiles[0][0],
        "out_unit": unit_at(by_layer[n_layers - 1][0]),
        "n_out": layers[-1].n_out,
        "out_groups": [
            (*unit_at(gi), groups[gi].r0, groups[gi].r1)
            for gi in by_layer[n_layers - 1]
        ],
        "unit_of_layer": [unit_at(by_layer[l][0]) for l in range(n_layers)],
        "groups": [
            {"group": groups[gi],
             "units": [unit_at(gi, t) for t in range(groups[gi].width)]}
            for gi in range(len(groups))
        ],
    }
    return cfg, states, pending, meta


def _inject_raster(pending, n_segments, in_tiles, raster, tick_period):
    """Pre-schedule the input spike train as AER events.

    Every stripe of layer 0 integrates the full raster (row sharding fans
    inputs out), so each event is replicated once per stripe, addressed to
    the column tile covering its axon.  Events land in the inboxes of the
    segments hosting those tiles; each inbox keeps half its capacity free
    for runtime spike traffic.
    """
    raster = np.asarray(raster)
    ts, axons = np.nonzero(raster)
    vals = raster[ts, axons]
    seg_l, addr_l, data_l, t_l = [], [], [], []
    for tiles in in_tiles:
        segs = np.array([sk[0] for sk in tiles], np.int32)
        slots = np.array([sk[1] for sk in tiles], np.int32)
        tidx = axons // XBAR
        seg_l.append(segs[tidx])
        addr_l.append((slots[tidx] << 16) | (axons % XBAR))
        data_l.append(vals)
        t_l.append((ts + 1) * tick_period)
    ev = {
        "seg": np.concatenate(seg_l) if seg_l else np.zeros(0, np.int32),
        "addr": np.concatenate(addr_l) if addr_l else np.zeros(0, np.int32),
        "data": np.concatenate(data_l) if data_l else np.zeros(0, np.int32),
        "t": np.concatenate(t_l) if t_l else np.zeros(0, np.int32),
    }
    cap = pending["valid"].shape[1]  # the built platform's in_cap
    boxes = {f: np.zeros((n_segments, cap), np.int32)
             for f in ("kind", "addr", "data", "t_avail")}
    valid = np.zeros((n_segments, cap), bool)
    count = np.zeros((n_segments,), np.int32)
    from repro.core import channel as ch
    for s in range(n_segments):
        m = ev["seg"] == s
        n = int(m.sum())
        assert n <= cap // 2, \
            f"{n} input events overflow segment {s}'s inbox (cap {cap}); " \
            "shorten or thin the raster, or raise in_cap (wide layers " \
            "replicate events per stripe)"
        boxes["kind"][s, :n] = ch.MSG_SPIKE
        boxes["addr"][s, :n] = ev["addr"][m]
        boxes["data"][s, :n] = ev["data"][m]
        boxes["t_avail"][s, :n] = ev["t"][m]
        valid[s, :n] = True
        count[s] = n
    out = {f: jnp.asarray(v) for f, v in boxes.items()}
    out["valid"] = jnp.asarray(valid)
    out["count"] = jnp.asarray(count)
    out["max_count"] = jnp.asarray(count)
    return jax.tree.map(lambda a, b: b, pending, out)


def output_spike_counts(states, meta) -> np.ndarray:
    """Per-neuron emitted-spike counts of the output layer, merged across
    its stripes by global neuron id."""
    counts = np.zeros(meta["n_out"], np.int64)
    for s, k, r0, r1 in meta["out_groups"]:
        counts[r0:r1] = np.asarray(states["cims"]["spike_counts"][s, k, : r1 - r0])
    return counts


def total_spikes(states) -> int:
    """All spikes emitted by every unit over the whole run."""
    return int(np.asarray(states["cims"]["spikes_total"]).sum())
